"""Render EXPERIMENTS.md tables from the dry-run JSON reports."""
import glob
import json
import os

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    return f"{b/1e6:.1f}MB"


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def load(d):
    cells = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        cells[(r["arch"], r["shape"])] = r
    return cells


def render(directory="experiments/dryrun/pod"):
    cells = load(directory)
    archs = sorted({a for a, _ in cells})
    lines = ["| arch | shape | kind | peak/dev | compute | memory | collective"
             " | dominant | useful ratio | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for a in archs:
        for sh in ORDER:
            r = cells.get((a, sh))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {a} | {sh} | — | — | — | — | — | SKIP"
                             f" (full-attention @500k) | — | — |")
                continue
            t = r.get("roofline", {})
            full = r.get("full", {})
            lines.append(
                f"| {a} | {sh} | {r['kind']} "
                f"| {fmt_bytes(full.get('peak_bytes_per_device', 0))} "
                f"| {fmt_s(t.get('compute_s', 0))} "
                f"| {fmt_s(t.get('memory_s', 0))} "
                f"| {fmt_s(t.get('collective_s', 0))} "
                f"| {t.get('dominant', '?')} "
                f"| {r.get('useful_flops_ratio', 0):.2f} "
                f"| {r.get('roofline_fraction', 0)*100:.2f}% |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    print(render(sys.argv[1] if len(sys.argv) > 1 else
                 "experiments/dryrun/pod"))
