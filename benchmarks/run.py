"""Benchmark harness — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and, with ``--json PATH``,
writes the same rows as a machine-readable JSON list for trajectory files):
  fig1_memory_<opt>        second-moment bytes for a BERT-large-ish layer set
  tbl3_convex_<dataset>    average cumulative online loss per learner
  fig3_spectral_decay      intrinsic dim + top-256 mass of EMA Kron factors
  lem1_fd_error            FD op-norm error vs the Lemma-1 bound
  fig2_lm_quality          small-LM loss after N steps per optimizer
  opt_step_time            wall-time per optimizer step (CPU, small shapes)
  opt_overhead_vs_adam     amortized sketchy step cost as a multiple of
                           adam's on the same block (unitless ratio row —
                           gated with a tolerance by scripts/bench_gate.py)
  opt_step_time_autotuned  pooled pallas step with a freshly force-tuned
                           cache (kernels/autotune.tune_into_cache) vs the
                           untuned bn_stack=1 defaults
  opt_step_time_multileaf  pooled-engine step over a >=100-leaf tree: wall
                           time + compiled-computation (jaxpr eqn) counts vs
                           the per-leaf dispatch baseline
  opt_step_time_kernels    pooled multi-leaf step per kernel_backend
                           ("xla" batched refs vs "pallas" grid-over-N
                           batched kernels; interpret mode on CPU)
  opt_step_time_{inline,async}_refresh  refresh-step direction critical
                           path per refresh_mode: async's one-step-stale
                           pipeline compiles ZERO eigh sites on the
                           direction path (overlap win), donated buffers
  lm_step_time_refresh_schedule  end-to-end reduced-LM step time,
                           synchronized vs staggered refresh phasing
                           (mean + spike max)
  bytes_on_wire_per_refresh  sketch-merge wire bytes per device per refresh
                           (distributed/sketch_merge.py int8 wire, log-depth
                           butterfly) vs the dense fp32 covariance
                           all-reduce at the same depth
  opt_step_time_sharded_stats  engine step under stats_reduction="sharded"
                           on an 8-device host-platform mesh (subprocess:
                           the bench process itself must keep ONE device)
  serve_latency_{constant,step}_traffic  p50/p99 inter-token latency of the
                           continuous-batching engine under load-generator
                           traffic (serve/loadgen.py shapes)
  monitor_overhead_per_window  FD gradient-monitor cost per feedback window
                           (serve/monitor.py: window x fd_update + the
                           window-boundary signal reads)
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

# rows accumulated for --json output: (name, us_per_call, derived)
_ROWS: list = []


def _row(name, us, derived):
    _ROWS.append({"name": name, "us_per_call": round(float(us), 1),
                  "derived": str(derived)})
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------


def bench_fig1_memory() -> None:
    """Paper Fig. 1: asymptotic optimizer memory, measured exactly on a
    BERT-large-like parameter set (4096x1024 FFN + 1024x1024 attn).  One
    metadata-driven accounting (api.second_moment_bytes over shape structs)
    covers every optimizer expressed through the shared engine."""
    from repro.core import api
    from repro.core.adam import AdamConfig, adam
    from repro.core.shampoo import ShampooConfig, shampoo
    from repro.core.sketchy import RankBudget, SketchyConfig, sketchy

    def _sk(rank, **kw):
        # fixed-rank rows via the primary RankBudget spelling (the bare
        # rank= alias is deprecated)
        return sketchy(SketchyConfig(
            rank_budget=RankBudget(min_k=rank, max_k=rank),
            block_size=1024, **kw))

    params = {
        "ffn_in": jnp.zeros((1024, 4096), jnp.float32),
        "ffn_out": jnp.zeros((4096, 1024), jnp.float32),
        "attn_qkv": jnp.zeros((1024, 3072), jnp.float32),
        "attn_o": jnp.zeros((1024, 1024), jnp.float32),
    }
    t0 = time.perf_counter()
    txs = [
        ("adam", adam(AdamConfig())),
        ("shampoo", shampoo(ShampooConfig(block_size=1024))),
        ("sketchy_l256", _sk(256)),
        ("sketchy_l64", _sk(64)),
        # quantized pool storage (core/quantize.py): the same sketch state
        # held in bf16 / per-block int8 between steps
        ("sketchy_l256_bf16", _sk(256, second_moment_dtype="bf16")),
        ("sketchy_l256_int8", _sk(256, second_moment_dtype="int8")),
        # async refresh pipeline (core/api.py pending slot): transient
        # double buffer, must cost ZERO accounted second-moment bytes —
        # this row is byte-equal to sketchy_l256 and the memory gate blocks
        # on it (scripts/bench_gate.py)
        ("sketchy_l256_async", _sk(256, refresh_mode="async")),
        # rank-budget allocator (core/sketchy.RankBudget): per-block active
        # ranks migrate inside fixed-capacity stacks, so the accounted
        # footprint MUST stay byte-equal to the static sketchy_l256 row —
        # the blocking memory gate holds this invariant (the (N,) int32
        # active-rank vector is role="count", outside the Fig. 1 budget)
        ("sketchy_l256_rank_budget", sketchy(SketchyConfig(
            rank_budget=RankBudget(min_k=64, max_k=256,
                                   policy="rho_greedy"),
            block_size=1024))),
    ]
    rows = [(name, api.second_moment_bytes(jax.eval_shape(tx.init, params)))
            for name, tx in txs]
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    base = dict(rows)["shampoo"]
    for name, b in rows:
        _row(f"fig1_memory_{name}", us, f"{b}B ({base / b:.1f}x vs shampoo)")


def bench_tbl3_convex(T: int = 400) -> None:
    """Paper Tbl. 3 on synthetic logistic streams (LIBSVM offline-N/A)."""
    from repro.core import sadagrad as oco

    def stream(seed, d, T, kind):
        rng = np.random.default_rng(seed)
        if kind == "lowrank":
            W = np.linalg.qr(rng.normal(size=(d, d // 2)))[0]
            feats = rng.normal(size=(T, d // 2)) @ W.T
        else:
            feats = rng.normal(size=(T, d)) * np.exp(-np.arange(d) / 8.0)
        w = rng.normal(size=d)
        y = np.sign(feats @ w + 0.1 * rng.normal(size=T))
        return feats * y[:, None]

    # jitted ONCE outside the per-step loop — the old per-step
    # ``jax.grad(lambda ...)`` built a fresh traced function every
    # iteration, so the bench measured trace overhead, not step time.
    @jax.jit
    def loss_and_grad(x, a):
        return jax.value_and_grad(
            lambda xx: jnp.log1p(jnp.exp(-a @ xx)))(x)

    for kind in ("decay", "lowrank"):
        A = stream(0, 32, T, kind)
        results = {}
        t0 = time.perf_counter()
        for name in ("s-adagrad", "adagrad", "ogd", "ada-fd", "fd-son",
                     "rfd-son"):
            init, step, needs = oco.LEARNERS[name]
            best = np.inf
            for lr in (0.05, 0.2, 0.5):
                for delta in ((1e-4, 1e-2) if needs["delta"] else (None,)):
                    st = init(32, 10) if needs["ell"] else init(32)  # paper: l=10
                    x = jnp.zeros((32,))
                    tot = 0.0
                    for a in A:
                        aj = jnp.asarray(a, jnp.float32)
                        loss, g = loss_and_grad(x, aj)
                        tot += float(loss)
                        args = (st, x, g, lr) + ((delta,) if delta is not None
                                                 else ())
                        x, st = step(*args)
                    best = min(best, tot / T)
            results[name] = best
        us = (time.perf_counter() - t0) * 1e6 / 6
        order = sorted(results, key=results.get)
        for name, v in results.items():
            _row(f"tbl3_convex_{kind}_{name}", us,
                 f"avg_loss={v:.4f} rank={order.index(name) + 1}")


def bench_fig3_spectral_decay(steps: int = 30) -> None:
    """Paper Fig. 3: EMA Kronecker-factor spectra during a small LM train."""
    from repro.configs.registry import get_reduced
    from repro.core.factory import OptimizerConfig, make_optimizer
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import model as model_lib
    from repro.train.trainer import make_train_step

    cfg = get_reduced("paper_lm_100m")
    tx = make_optimizer(OptimizerConfig(name="adam", learning_rate=5e-3,
                                        schedule="constant"))
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    state = tx.init(params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8))
    # donate=False: grad_fn reads params before each step in the same loop
    step = jax.jit(make_train_step(cfg, tx, donate=False))
    beta2 = 0.999
    L = None
    t0 = time.perf_counter()
    grad_fn = jax.jit(jax.grad(
        lambda p, b: model_lib.loss_fn(cfg, p, b)))
    for t in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(t).items()}
        g = grad_fn(params, batch)["layers"]["mlp"]["w_gate"][0]
        GG = np.asarray(g, np.float64) @ np.asarray(g, np.float64).T
        L = GG if L is None else beta2 * L + GG
        params, state, _ = step(params, state, batch)
    us = (time.perf_counter() - t0) * 1e6 / steps
    lam = np.maximum(np.linalg.eigvalsh(L)[::-1], 0)
    d = len(lam)
    intrinsic = lam.sum() / max(lam[0], 1e-12)
    k = max(d // 4, 1)
    topk = lam[:k].sum() / max(lam.sum(), 1e-12)
    _row("fig3_spectral_decay", us,
         f"dim={d} intrinsic_dim={intrinsic:.1f} top{k}_mass={topk:.3f}")


def bench_lem1_fd_error(T: int = 200) -> None:
    from repro.core.fd import fd_covariance, fd_init, fd_update

    rng = np.random.default_rng(0)
    d, ell = 64, 16
    basis = np.linalg.qr(rng.normal(size=(d, d)))[0]
    scales = np.exp(-np.arange(d) / 4.0)
    st = fd_init(d, ell)
    G = np.zeros((d, d))
    t0 = time.perf_counter()
    for _ in range(T):
        g = basis @ (scales * rng.normal(size=d))
        G += np.outer(g, g)
        st = fd_update(st, jnp.asarray(g, jnp.float32))
    us = (time.perf_counter() - t0) * 1e6 / T
    lam = np.maximum(np.linalg.eigvalsh(G)[::-1], 0)
    bound = min(lam[k:].sum() / (ell - k) for k in range(ell))
    err = np.linalg.norm(G - np.asarray(fd_covariance(st)), 2)
    _row("lem1_fd_error", us,
         f"op_err={err:.3f} rho={float(st.rho):.3f} lemma1_bound={bound:.3f}")


def bench_fig2_lm_quality(steps: int = 60) -> None:
    """Paper Fig. 2 analogue: small-LM quality per optimizer, same budget."""
    from repro.configs.registry import get_reduced
    from repro.core.factory import OptimizerConfig, make_optimizer
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import model as model_lib
    from repro.train.trainer import make_train_step

    from repro.core import api
    from repro.core.sketchy import RankBudget

    cfg = get_reduced("paper_lm_100m")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8))
    # the rank_budget row trains at HALF the fixed-rank row's total sketch
    # rank (rho_greedy migration inside max_k=8-capacity stacks) — an
    # advisory quality row, not a gated one.  Block count probed from shape
    # structs so the explicit total tracks the reduced arch.
    params0 = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    probe = make_optimizer(OptimizerConfig(
        name="sketchy", rank=8, block_size=32, update_every=2,
        total_steps=steps, schedule="constant"))
    nblocks = sum(len(g["k"]) for g in api.rank_allocation(
        jax.eval_shape(probe.init, params0))["groups"].values())
    half_budget = RankBudget(total=max(nblocks * 8 // 2, nblocks * 2),
                             min_k=2, max_k=8, policy="rho_greedy",
                             realloc_every=1)
    variants = [("sketchy", 5e-3, None), ("shampoo", 5e-3, None),
                ("adam", 5e-3, None), ("rank_budget", 5e-3, half_budget)]
    for name, lr, budget in variants:
        tx = make_optimizer(OptimizerConfig(
            name="sketchy" if budget is not None else name,
            learning_rate=lr, rank=8, rank_budget=budget, block_size=32,
            update_every=2, total_steps=steps, schedule="constant"))
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        state = tx.init(params)
        step = make_train_step(cfg, tx)   # jitted + donated internally
        t0 = time.perf_counter()
        losses = []
        for t in range(steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(t).items()}
            params, state, m = step(params, state, batch)
            losses.append(float(m["loss"]))
        us = (time.perf_counter() - t0) * 1e6 / steps
        _row(f"fig2_lm_quality_{name}", us,
             f"loss_first5={np.mean(losses[:5]):.3f} "
             f"loss_last5={np.mean(losses[-5:]):.3f}")


def bench_opt_step_time(iters: int = 20) -> None:
    from repro.core.factory import OptimizerConfig, make_optimizer

    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(1024, 1024)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(1024, 1024)), jnp.float32)}
    times = {}
    for name in ("sketchy", "shampoo", "adam"):
        tx = make_optimizer(OptimizerConfig(name=name, rank=256,
                                            block_size=1024, update_every=10,
                                            schedule="constant"))
        state = tx.init(params)
        upd = jax.jit(lambda g, s, p: tx.update(g, s, p))
        u, state = upd(g, state, params)  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            u, state = upd(g, state, params)
        jax.block_until_ready(u)
        us = (time.perf_counter() - t0) * 1e6 / iters
        times[name] = us
        _row(f"opt_step_time_{name}", us, "1024x1024 block, update_every=10")
    # the paper's practical pitch: amortized (update_every=10) Sketchy step
    # cost as a multiple of Adam's on the same block — a unitless ratio, so
    # the bench gate can hold it to a tolerance that raw wall-clock rows on
    # shared runners can't keep
    _row("opt_overhead_vs_adam", times["sketchy"],
         f"ratio={times['sketchy'] / times['adam']:.2f}x sketchy vs adam "
         f"(1024x1024 block, update_every=10 amortized, "
         f"shampoo={times['shampoo'] / times['adam']:.2f}x)")


def _count_prim(jaxpr, substr: str = "") -> int:
    """Call sites of primitives whose name contains ``substr``, recursing
    into sub-jaxprs (cond branches, vmapped/scanned bodies).  With the empty
    substring this is the total equation count — the 'how many compiled
    optimizer computations' measure: per-leaf dispatch multiplies it by the
    leaf count, pooling doesn't."""
    def subs(v):
        if hasattr(v, "eqns"):
            yield v
        elif hasattr(v, "jaxpr"):
            yield from subs(v.jaxpr)
        elif isinstance(v, (list, tuple)):
            for x in v:
                yield from subs(x)

    n = sum(substr in eqn.primitive.name for eqn in jaxpr.eqns)
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for sj in subs(v):
                n += _count_prim(sj, substr)
    return n


def bench_opt_step_time_multileaf(n_leaves: int = 128, iters: int = 10) -> None:
    """Pooled-engine dispatch over a many-leaf tree (the transformer case:
    hundreds of same-shaped parameters).  Derived column reports the pooled
    jaxpr equation count next to the per-leaf baseline (= n_leaves x the
    single-leaf engine's count — what the pre-pool engine compiled)."""
    from repro.core.sketchy import SketchyConfig, sketchy

    rng = np.random.default_rng(0)
    cfg = SketchyConfig(rank=4, block_size=16, update_every=10)
    mk = lambda: jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
    params = {f"w{i:03d}": mk() for i in range(n_leaves)}
    g = {k: mk() for k in params}
    tx = sketchy(cfg)
    state = tx.init(params)

    pooled_jaxpr = jax.make_jaxpr(lambda gg, s: tx.update(gg, s))(g, state).jaxpr
    pooled_eqns = _count_prim(pooled_jaxpr)
    pooled_eigh = _count_prim(pooled_jaxpr, "eig")
    p1, g1 = {"w": mk()}, {"w": mk()}
    tx1 = sketchy(cfg)
    s1 = tx1.init(p1)
    single_jaxpr = jax.make_jaxpr(lambda gg, s: tx1.update(gg, s))(g1, s1).jaxpr
    per_leaf_eqns = n_leaves * _count_prim(single_jaxpr)
    per_leaf_eigh = n_leaves * _count_prim(single_jaxpr, "eig")

    upd = jax.jit(lambda gg, s: tx.update(gg, s))
    u, st = upd(g, state)   # compile
    jax.block_until_ready(u)
    t0 = time.perf_counter()
    for _ in range(iters):
        u, st = upd(g, st)
    jax.block_until_ready(u)
    us = (time.perf_counter() - t0) * 1e6 / iters
    _row("opt_step_time_multileaf", us,
         f"leaves={n_leaves} pooled_eqns={pooled_eqns} "
         f"per_leaf_eqns={per_leaf_eqns} "
         f"reduction={per_leaf_eqns / pooled_eqns:.1f}x "
         f"eigh_sites={pooled_eigh}_vs_{per_leaf_eigh}")


def bench_opt_step_time_kernels(n_leaves: int = 32, iters: int = 5) -> None:
    """Kernel-backend comparison on the pooled multi-leaf config: the same
    packed (N, bs_m, bs_n) dispatch, once through the pure-XLA batched refs
    and once through the grid-over-N batched Pallas kernels (Mosaic on TPU;
    interpret mode on CPU, where the row is a correctness/overhead probe, not
    a speed claim).  update_every=1 so every step pays the batched gram +
    fused low-rank apply."""
    from repro.core import pool
    from repro.core.sketchy import SketchyConfig, sketchy

    rng = np.random.default_rng(0)
    mk = lambda: jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    params = {f"w{i:03d}": mk() for i in range(n_leaves)}
    g = {k: mk() for k in params}
    index = pool.build_index(((32, 32),) * n_leaves, 32)
    for backend in ("xla", "pallas"):
        tx = sketchy(SketchyConfig(rank=8, block_size=32, update_every=1,
                                   kernel_backend=backend))
        state = tx.init(params)
        upd = jax.jit(lambda gg, s: tx.update(gg, s))
        u, st = upd(g, state)   # warmup/compile
        jax.block_until_ready(u)
        t0 = time.perf_counter()
        for _ in range(iters):
            u, st = upd(g, st)
        jax.block_until_ready(u)
        us = (time.perf_counter() - t0) * 1e6 / iters
        _row(f"opt_step_time_kernels_{backend}", us,
             f"leaves={n_leaves} pooled_blocks={index.total_blocks} "
             f"rank=8 block=32 update_every=1")


def bench_opt_step_time_autotuned(n_leaves: int = 32, iters: int = 5) -> None:
    """Shape-aware autotuner payoff (kernels/autotune.py) on the pooled
    pallas step of ``bench_opt_step_time_kernels``: the same engine measured
    with tuning OFF (every kernel pinned to the bn_stack=1 defaults) and
    then with a freshly force-tuned cache (``tune_into_cache`` on the pool
    shapes this config traces) picked up by a fresh tx/jit.  Configs resolve
    at trace time, so the tuned step pays zero per-step lookup cost; the
    derived column carries the untuned baseline and the speedup."""
    from repro.core.sketchy import SketchyConfig, sketchy
    from repro.kernels import autotune

    rng = np.random.default_rng(0)
    mk = lambda: jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    params = {f"w{i:03d}": mk() for i in range(n_leaves)}
    g = {k: mk() for k in params}

    def measure() -> float:
        tx = sketchy(SketchyConfig(rank=8, block_size=32, update_every=1,
                                   kernel_backend="pallas"))
        state = tx.init(params)
        upd = jax.jit(lambda gg, s: tx.update(gg, s))
        u, st = upd(g, state)   # warmup/compile
        jax.block_until_ready(u)
        t0 = time.perf_counter()
        for _ in range(iters):
            u, st = upd(g, st)
        jax.block_until_ready(u)
        return (time.perf_counter() - t0) * 1e6 / iters

    # the pool shapes this config traces: gram over [U*sqrt(beta2*s) | G]
    # -> (N, d, ell + bs_n); fused low-rank apply -> (N, d, ell, bs_n)
    specs = [("batched_gram", (n_leaves, 32, 40), "float32"),
             ("batched_lowrank_apply", (n_leaves, 32, 8, 32), "float32")]
    cur = autotune._resolve()
    prev_path, prev_mode = cur["path"], cur["mode"]
    import tempfile
    try:
        autotune.reload(mode="off")
        untuned_us = measure()
        with tempfile.TemporaryDirectory() as tmp:
            autotune.reload(path=os.path.join(tmp, "cache.json"),
                            mode="auto")
            t0 = time.perf_counter()
            autotune.tune_into_cache(specs)
            tune_ms = (time.perf_counter() - t0) * 1e3
            tuned_us = measure()
    finally:
        autotune.reload(path=prev_path, mode=prev_mode)
    _row("opt_step_time_autotuned", tuned_us,
         f"speedup={untuned_us / tuned_us:.2f}x vs untuned bn_stack=1 "
         f"({untuned_us:.1f}us), one-off tune_cost={tune_ms:.0f}ms, "
         f"leaves={n_leaves} rank=8 block=32 pallas")


def bench_opt_step_time_async_refresh(n_leaves: int = 64,
                                      iters: int = 10) -> None:
    """Refresh-step critical path, inline vs async (ISSUE 7 tentpole row).

    What overlapped execution hides is the time from gradient arrival to
    the update DIRECTION being ready — the refresh itself continues in the
    shadow of the next forward/backward.  On the single-stream CPU backend
    that latency is measured by the direction-only program
    ``jit(lambda g, s: tx.update(g, s)[0])``: XLA dead-code-eliminates the
    state outputs, and under async the refresh (eigh + shrink) is dead code
    for the direction — the compiled program has ZERO eigh call sites —
    while inline's direction data-depends on the refresh it just computed.
    Both engines are pinned to a refresh-boundary count (the worst-case
    step; off-boundary steps are identical by construction).  The derived
    column carries the eigh site counts and the full donated steady-state
    step time (refresh amortized over ``update_every``) for both modes.
    """
    from repro.core.sketchy import SketchyConfig, sketchy

    rng = np.random.default_rng(0)
    mk = lambda: jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    params = {f"w{i:03d}": mk() for i in range(n_leaves)}
    g = {k: mk() for k in params}
    update_every = 10
    out = {}
    for mode in ("inline", "async"):
        tx = sketchy(SketchyConfig(rank=16, block_size=64,
                                   update_every=update_every,
                                   refresh_mode=mode))
        # full steady-state step (donated opt_state, refresh amortized)
        full = jax.jit(lambda gg, s: tx.update(gg, s), donate_argnums=(1,))
        st = tx.init(params)
        u, st = full(g, st)     # compile + leave count=1
        jax.block_until_ready(u)
        t0 = time.perf_counter()
        for _ in range(iters * update_every):
            u, st = full(g, st)
        jax.block_until_ready(u)
        full_us = (time.perf_counter() - t0) * 1e6 / (iters * update_every)

        # direction-only program at a refresh-boundary count: advance a
        # fresh state to count == update_every, then measure with the state
        # held fixed (every call sees the refresh-due branch)
        st = tx.init(params)
        for _ in range(update_every):
            _, st = jax.jit(lambda gg, s: tx.update(gg, s))(g, st)
        dir_fn = jax.jit(lambda gg, s: tx.update(gg, s)[0])
        # count eigh in the LOWERED program: lowering dead-code-eliminates
        # the discarded state outputs (the traced jaxpr itself keeps them)
        eigh_sites = dir_fn.lower(g, st).as_text().count("eigh")
        u = dir_fn(g, st)       # compile
        jax.block_until_ready(u)
        t0 = time.perf_counter()
        for _ in range(iters):
            u = dir_fn(g, st)
        jax.block_until_ready(u)
        us = (time.perf_counter() - t0) * 1e6 / iters
        out[mode] = (us, full_us, eigh_sites)

    i_us, i_full, i_eigh = out["inline"]
    a_us, a_full, a_eigh = out["async"]
    assert a_eigh == 0, f"async direction path still compiles eigh ({a_eigh})"
    _row("opt_step_time_inline_refresh", i_us,
         f"direction critical path at refresh boundary, eigh_sites={i_eigh} "
         f"full_step={i_full:.1f}us leaves={n_leaves} rank=16 "
         f"update_every={update_every}")
    _row("opt_step_time_async_refresh", a_us,
         f"direction critical path at refresh boundary, eigh_sites={a_eigh} "
         f"full_step={a_full:.1f}us overlap_win={i_us / a_us:.1f}x "
         f"vs inline (donated double buffer)")


def bench_lm_step_time_refresh_schedule(steps: int = 24) -> None:
    """End-to-end step time on the reduced paper_lm_100m, synchronized vs
    staggered refresh phasing (ISSUE 7 satellite): same amortized eigh
    budget, but staggered flattens the every-``update_every``-steps spike
    into ~N/k blocks per step.  Derived reports mean and max step wall time
    per schedule — the max is the spike the staggered schedule removes."""
    from repro.configs.registry import get_reduced
    from repro.core.factory import OptimizerConfig, make_optimizer
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import model as model_lib
    from repro.train.trainer import make_train_step

    cfg = get_reduced("paper_lm_100m")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8))
    out = {}
    for sched in ("synchronized", "staggered"):
        tx = make_optimizer(OptimizerConfig(
            name="sketchy", learning_rate=5e-3, rank=8, block_size=32,
            update_every=4, total_steps=steps, schedule="constant",
            refresh_schedule=sched))
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        state = tx.init(params)
        step = make_train_step(cfg, tx)   # jitted + donated internally
        times = []
        for t in range(steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(t).items()}
            t0 = time.perf_counter()
            params, state, m = step(params, state, batch)
            jax.block_until_ready(m["loss"])
            times.append(time.perf_counter() - t0)
        times = np.array(times[4:]) * 1e6   # drop compile/warmup steps
        out[sched] = (times.mean(), times.max())
    s_mean, s_max = out["synchronized"]
    g_mean, g_max = out["staggered"]
    _row("lm_step_time_refresh_schedule", g_mean,
         f"staggered mean={g_mean:.0f}us max={g_max:.0f}us vs synchronized "
         f"mean={s_mean:.0f}us max={s_max:.0f}us (reduced paper_lm_100m, "
         f"update_every=4)")


def bench_bytes_on_wire_per_refresh(P: int = 4) -> None:
    """Distributed-FD wire cost (ISSUE 6 acceptance row): bytes each device
    ships per refresh through the log-depth butterfly
    (``distributed/sketch_merge.pack_wire``: deflated column dropped, int8
    values + one fp32 scale + fp32 rho per block, both sketch sides) vs the
    dense alternative — recursive-doubling all-reduce of both d x d fp32
    covariance factors at the same log2(P) depth.  Measured on real packed
    structures, not a formula."""
    from repro.core.fd import fd_init, fd_update_batched, FDState
    from repro.distributed import sketch_merge

    d, ell, N = 256, 64, 1
    rng = np.random.default_rng(0)
    st0 = fd_init(d, ell)
    st = FDState(st0.eigvecs[None], st0.eigvals[None], st0.rho[None])
    st = fd_update_batched(
        st, jnp.asarray(rng.normal(size=(N, d, 8)), jnp.float32))
    t0 = time.perf_counter()
    wire = sketch_merge.pack_wire(st, "int8")
    per_round = sketch_merge.wire_bytes(wire)
    us = (time.perf_counter() - t0) * 1e6
    rounds = (P - 1).bit_length()      # log2(P) butterfly rounds
    sketch_bytes = rounds * 2 * per_round          # left + right sketches
    dense_bytes = rounds * 2 * d * d * 4           # both fp32 covariances
    _row("bytes_on_wire_per_refresh", us,
         f"{sketch_bytes}B on wire (dense_fp32={dense_bytes}B, "
         f"{dense_bytes / sketch_bytes:.1f}x less, P={P} d={d} ell={ell} "
         f"int8 wire, {per_round}B/round/side)")


def bench_opt_step_time_sharded_stats(iters: int = 10) -> None:
    """Engine step wall-time with stats_reduction="sharded" on an 8-device
    host-platform CPU mesh next to the replicated step on the same shapes.
    Runs in a subprocess: this process must keep seeing one device (the
    dry-run contract), and XLA only fakes the device count at startup."""
    code = f"""
import time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import sketchy as sk
from repro.distributed import reduce as dreduce
from repro.sharding.rules import shard_map

rng = np.random.default_rng(0)
params = {{f"w{{i}}": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
          for i in range(4)}}
grads = {{k: jnp.asarray(rng.normal(size=(8,) + v.shape), jnp.float32)
         for k, v in params.items()}}
gmean = jax.tree.map(lambda g: g.mean(0), grads)
mesh = jax.make_mesh((8,), ("data",))

def bench(tx, fn, *args):
    state = tx.init(params)
    step = jax.jit(fn)
    out = step(*args, state)            # warmup/compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range({iters}):
        out = step(*args, out[1])
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6 / {iters}

cfg = dict(rank=16, block_size=64, update_every=2)
tx_r = sk.sketchy(sk.SketchyConfig(**cfg))
us_r = bench(tx_r, lambda g, s: tx_r.update(g, s, params), gmean)

tx_s = sk.sketchy(sk.SketchyConfig(stats_reduction="sharded", **cfg))
def sharded(g, s):
    def body(gl, s):
        gl = jax.tree.map(lambda x: x[0], gl)
        gm = dreduce.pmean(gl, "data")
        with dreduce.local_gradients(gl):
            return tx_s.update(gm, s, params)
    return shard_map(body, mesh=mesh, in_specs=(P("data"), P()),
                     out_specs=(P(), P()), check_vma=False)(g, s)
us_s = bench(tx_s, sharded, grads)
print(f"SHARDED_US={{us_s:.1f}} REPL_US={{us_r:.1f}}")
"""
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": os.pathsep.join(
               [os.path.join(os.path.dirname(os.path.dirname(
                   os.path.abspath(__file__))), "src")] +
               ([os.environ["PYTHONPATH"]]
                if os.environ.get("PYTHONPATH") else []))}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    if r.returncode != 0:
        _row("opt_step_time_sharded_stats", 0.0,
             f"SUBPROCESS_FAILED: {r.stderr[-200:]!r}")
        return
    kv = dict(tok.split("=") for tok in r.stdout.split() if "=" in tok)
    us_s, us_r = float(kv["SHARDED_US"]), float(kv["REPL_US"])
    _row("opt_step_time_sharded_stats", us_s,
         f"8-device butterfly merge, replicated_same_shapes={us_r:.1f}us "
         f"4x(64x64) leaves rank=16 update_every=2")


def bench_serve_latency(ticks: int = 16) -> None:
    """Serve rows (ISSUE 10): the continuous-batching engine driven by the
    deterministic load generator, one row per traffic shape.  ``us_per_call``
    is mean wall time per engine step; the derived column carries p50/p99
    inter-token latency read off the request handles' per-token timestamps —
    the step shape's post-jump p99 is the number the slot-reuse redesign is
    about (queued requests claim freed lanes instead of waiting for the
    whole static batch)."""
    from repro.configs.registry import get_reduced
    from repro.models import model as model_lib
    from repro.serve import (Engine, LoadGenerator, ServeConfig,
                             TrafficConfig)

    cfg = get_reduced("paper_lm_100m")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    for shape in ("constant", "step"):
        gen = LoadGenerator(TrafficConfig(
            shape=shape, rate=1.0, ticks=ticks, step_at=ticks // 2,
            step_mult=3.0, prompt_len=6, new_tokens=6), cfg.vocab_size)
        eng = Engine(cfg, params, ServeConfig(batch=4, max_seq=32))
        eng.step()   # pay the decode compile outside the timed run
        handles = []
        t0 = time.perf_counter()
        for tick in range(ticks):
            for req in gen.arrivals(tick):
                handles.append(eng.submit(req))
            eng.step()
        done = eng.drain()
        wall = time.perf_counter() - t0
        steps = eng.step_count - 1
        lat = np.array([t1 - ta for h in handles for ta, t1 in
                        zip(h.token_times, h.token_times[1:])])
        p50 = np.percentile(lat, 50) * 1e3 if lat.size else 0.0
        p99 = np.percentile(lat, 99) * 1e3 if lat.size else 0.0
        _row(f"serve_latency_{shape}_traffic", wall * 1e6 / max(steps, 1),
             f"p50={p50:.2f}ms p99={p99:.2f}ms tokens="
             f"{sum(len(h.tokens) for h in handles)} requests={len(handles)} "
             f"steps={steps} batch=4")


def bench_monitor_overhead_per_window(d: int = 4096, windows: int = 20) -> None:
    """Serve-time telemetry cost (ISSUE 10): one full monitor window —
    ``window`` jitted rank-ell fd_updates on a (d,) gradient plus the
    boundary signal reads (leading eig, pressure, drift angle, policy) —
    on the flattened-head gradient size the adaptation loop actually
    monitors."""
    from repro.serve import GradientMonitor, MonitorConfig

    cfg = MonitorConfig(ell=8, window=8, top_k=4)
    mon = GradientMonitor(d, cfg)
    rng = np.random.default_rng(0)
    grads = [rng.standard_normal(d).astype(np.float32)
             for _ in range(cfg.window)]
    for g in grads:     # compile + first boundary
        mon.observe(g)
    t0 = time.perf_counter()
    for _ in range(windows):
        for g in grads:
            mon.observe(g)
    us = (time.perf_counter() - t0) * 1e6 / windows
    per_grad = us / cfg.window
    _row("monitor_overhead_per_window", us,
         f"per_grad={per_grad:.1f}us d={d} ell={cfg.ell} "
         f"window={cfg.window} (fd_update stream + boundary signals)")


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--json", metavar="PATH", default=None,
                   help="also write the rows as a JSON list (machine-"
                        "readable trajectory output, e.g. BENCH_opt.json)")
    args = p.parse_args(argv)

    _ROWS.clear()   # repeat-safe: direct bench_* calls may have accumulated
    print("name,us_per_call,derived")
    bench_fig1_memory()
    bench_lem1_fd_error()
    bench_tbl3_convex()
    bench_fig3_spectral_decay()
    bench_fig2_lm_quality()
    bench_opt_step_time()
    bench_opt_step_time_multileaf()
    bench_opt_step_time_kernels()
    bench_opt_step_time_autotuned()
    bench_opt_step_time_async_refresh()
    bench_lm_step_time_refresh_schedule()
    bench_bytes_on_wire_per_refresh()
    bench_opt_step_time_sharded_stats()
    bench_serve_latency()
    bench_monitor_overhead_per_window()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(_ROWS, f, indent=1)
        print(f"wrote {len(_ROWS)} rows to {args.json}")


if __name__ == "__main__":
    main()
