"""Paper Appendix A: S-AdaGrad vs FD baselines on online logistic regression
(synthetic streams; see DESIGN.md §6 for the LIBSVM note).

    PYTHONPATH=src python examples/convex_online.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sadagrad as oco


def make_stream(seed=0, d=32, T=500):
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(T, d)) * np.exp(-np.arange(d) / 8.0)
    w = rng.normal(size=d)
    y = np.sign(feats @ w + 0.1 * rng.normal(size=T))
    return feats * y[:, None]


def main():
    d, T, ell = 32, 500, 6
    A = make_stream(d=d, T=T)
    print(f"online logistic regression: d={d} T={T} sketch ell={ell}")
    for name in ("s-adagrad", "adagrad", "ogd", "ada-fd", "fd-son", "rfd-son"):
        init, step, needs = oco.LEARNERS[name]
        best, best_lr = np.inf, None
        for lr in (0.05, 0.2, 0.5):
            for delta in ((1e-4, 1e-2) if needs["delta"] else (None,)):
                st = init(d, ell) if needs["ell"] else init(d)
                x = jnp.zeros((d,))
                tot = 0.0
                for a in A:
                    aj = jnp.asarray(a, jnp.float32)
                    tot += float(jnp.log1p(jnp.exp(-aj @ x)))
                    g = jax.grad(lambda x: jnp.log1p(jnp.exp(-aj @ x)))(x)
                    args = (st, x, g, lr) + ((delta,) if delta is not None else ())
                    x, st = step(*args)
                if tot < best:
                    best, best_lr = tot, lr
        print(f"  {name:10s} avg cumulative loss {best / T:.4f} (lr={best_lr})")


if __name__ == "__main__":
    main()
