"""Quickstart: Sketchy (S-Shampoo) through the unified Preconditioner API.

    PYTHONPATH=src python examples/quickstart.py

What this demonstrates:
  * ``make_optimizer`` builds a labelled ``named_chain`` (clip -> precond ->
    momentum -> lr) wrapped in ``inject_hyperparams`` — the learning rate
    lives in optimizer state.
  * Every state leaf carries a ``StateMeta`` annotation; memory accounting
    and introspection are one metadata traversal, no optimizer-specific
    types anywhere.
  * Hyperparameters can be mutated mid-run (``api.set_hyperparams``) without
    rebuilding or re-jitting the chain — the serve/elastic re-mesh path.

Memory knobs
------------
Second-moment memory (the paper's Fig. 1 quantity) stacks two independent
knobs on top of Adam's O(d^2-per-layer) baseline:

  * ``rank`` — the FD sketch size ell: O((m+n) * ell) per block instead of
    Shampoo's O(m^2 + n^2).
  * ``second_moment_dtype`` — how the second-moment state is *stored*
    between steps (core/quantize.py): ``"fp32"`` (default, bitwise parity),
    ``"bf16"`` (2x smaller), or ``"int8"`` (quantized matrix factors with
    per-block fp32 scales, plus whole-leaf-scaled int8 diag-fallback
    accumulators for vector/scalar params, ~4x smaller).  Compute always
    dequantizes to f32.

Measured via ``api.second_moment_bytes`` on this demo's reduced config
(rank 8, block 32; the per-block fp32 eigenvalue ladders and scales keep the
small-model ratio under 4x — it steepens at paper scale where the matrix
factors dominate):

    OptimizerConfig(name="sketchy", rank=8, ...)                     301.5kB
    OptimizerConfig(..., second_moment_dtype="int8")                  84.2kB  (3.6x)

``main()`` below prints the exact before/after int8 numbers for the current
config (no state materialization — ``jax.eval_shape`` over ``tx.init``).

Rank budget knobs
-----------------
``rank`` pins every block to the same sketch size.  The primary spelling is
``OptimizerConfig(rank_budget=RankBudget(...))`` (core/sketchy.py): one
fixed TOTAL sketch rank shared by all pooled blocks, with a per-block
allocation policy:

  * ``RankBudget(min_k=r, max_k=r, policy="static")`` — what ``rank=r``
    normalizes to; every block at capacity forever, bitwise-identical to
    the pre-budget engine.
  * ``RankBudget(total=K, min_k=..., max_k=..., policy="rho_greedy",
    realloc_every=j)`` — every ``j * update_every`` steps the total K is
    re-poured across blocks by descending escaped-mass pressure
    ``rho / (trace + rho)``: blocks whose sketch drops the most mass grow
    (masked zero columns unmask), over-provisioned blocks shrink by exact
    Robust-FD deflation (dropped eigenvalue mass folds into ``rho``).

Memory does NOT follow the active ranks: stacks are allocated at ``max_k``
capacity and ``second_moment_bytes`` is byte-identical to a static run at
``rank=max_k`` (the ``fig1_memory_sketchy_l256_rank_budget`` row is held
byte-equal to ``sketchy_l256`` by the blocking memory gate).  What moves is
where the *effective* rank sits — measured live via
``api.rank_allocation(opt_state)``, printed below: per pool group the
active ranks ``k``, per-block escaped mass ``rho``, and ``budget_share =
k / K``.  The deprecated ``SketchyConfig(rank=...)`` spelling still works
(DeprecationWarning; see the CHANGES.md migration table), and pre-budget
fixed-rank checkpoints restore into budgeted runs via a migration shim
(train/checkpoint.py).

Distributed sketching
---------------------
Under data parallelism the default (``stats_reduction="replicated"``)
all-reduces dense gradients and has every replica maintain an identical
sketch.  ``OptimizerConfig(stats_reduction="sharded")`` (or
``launch/train.py --stats-reduction sharded``) instead has each shard run
the FD update on its *local* gradients and, at refresh time, merge the
pooled sketch stacks across the ``data`` mesh axis with a log-depth
butterfly of ``fd_merge`` rounds (src/repro/distributed/): each round ships
``~(ell-1) * d`` int8 per block (sqrt(s)-weighted factors on the shared
int8 wire, escaped mass ``rho`` summed alongside) instead of ``d^2`` fp32 —
16x fewer bytes on the wire at d=256, ell=64 (``bytes_on_wire_per_refresh``
benchmark row).  The update direction stays deterministic: with a 1-sized
(or unbound) data axis the sharded path is bitwise-identical to replicated,
and the merged sketch obeys the same FD error bound as a single-stream
sketch of all shards' gradients (tests/test_distributed.py).

Kernel tuning knobs
-------------------
The pooled hot path (batched gram + fused low-rank apply over packed
``(N, bs_m, bs_n)`` stacks) runs through the kernel registry
(``kernels/registry.py``); three knobs control how those kernels execute:

  * ``kernel_backend`` — ``"auto"`` (default: Pallas on TPU, XLA batched
    refs elsewhere; ``REPRO_KERNEL_BACKEND`` env overrides), ``"pallas"``,
    or ``"xla"``.
  * Tile configs come from the shape-aware autotuner
    (``kernels/autotune.py``): each Pallas entry point looks up a measured
    ``(bn_stack, bk, bd, bn)`` winner for its exact (platform, kernel,
    padded pool shape, storage dtype) at *trace* time — tuned steps pay
    zero per-step lookup cost.  ``REPRO_TUNE_MODE`` picks the policy:
    ``"auto"`` (default: use the committed ``kernels/tune_cache.json``
    fixture, fall back to safe defaults on a miss), ``"off"`` (always
    defaults — the pinned-parity baseline), or ``"force"`` (measure and
    persist on every miss).  ``REPRO_TUNE_CACHE`` points at an alternative
    cache file; ``python -m repro.kernels.autotune tune|show|validate``
    maintains one from the command line, and the ``opt_step_time_autotuned``
    benchmark row tracks the payoff vs the untuned defaults.
  * ``quantized_epilogue`` — with ``second_moment_dtype="int8"``, ``"auto"``
    (default) fuses dequantize/requantize into the Pallas kernels whenever
    the pallas backend is resolved and stats are replicated: the int8 pool
    containers flow straight into the batched FD methods (scale-folded
    gram/apply, in-kernel requantized eigenvector stacks), so the f32
    factor stack is never materialized at the pool boundary.  ``"off"``
    always dequantizes at the boundary (the PR-4 baseline numerics);
    ``"on"`` forces the fused math on any backend (the XLA mirror of the
    same scale-folded computation — useful for A/B-ing numerics).  Sketchy
    only; shampoo's root solve keeps f32 factors.

Step-time knobs
---------------
Three independent knobs trade when the eigh-heavy refresh work happens for
wall-clock step time; none of them changes the statistics stream:

  * ``refresh_schedule`` — *which blocks* refresh each step.
    ``"synchronized"`` (default) refreshes every pooled block every
    ``update_every`` steps: one big eigh spike, cheapest mean step time.
    ``"staggered"`` spreads ~N/update_every blocks across every step: same
    amortized cost, flat step-time profile.  Measured end-to-end on the
    reduced paper_lm_100m (``lm_step_time_refresh_schedule`` benchmark
    row): staggered consistently cuts the worst-step spike (~1.7-2.3x
    across runs) while mean step time stays within CPU run-to-run noise
    (synchronized won 2 of 3 runs by ~10-15%), so synchronized stays the
    default — pick staggered when stragglers/latency spikes hurt more
    than throughput (e.g. a synchronous data-parallel pod where the
    slowest step gates everyone).
  * ``refresh_mode`` — *when* the refresh lands.  ``"inline"`` (default)
    computes it on the step's critical path.  ``"async"`` launches it at
    step t from the just-updated stats into a transient double-buffered
    pending slot and commits it at step t+1, so the eigh (and the
    distributed butterfly merge) overlap with the next step's
    forward/backward; the update direction is one refresh stale, but the
    committed statistics are bitwise step-shifted-equal to inline
    (tests/test_async_refresh.py).  The direction's compiled critical path
    drops every eigh call site (``opt_step_time_async_refresh`` row:
    ~15x shorter at refresh boundaries on the multileaf CPU bench).
  * ``profile_annotations=True`` — named_scope + profiler.TraceAnnotation
    spans around update_stats/refresh/precondition/commit (and the
    butterfly merge rounds), for reading the overlap off a profiler trace.

``make_train_step`` jits with params and optimizer state DONATED
(``donate_argnums=(0, 1)``): the step reuses the input buffers for its
outputs, so even the async pending slot adds no steady-state copies beyond
its double buffer.  Keep references out of donated trees (pass
``donate=False`` if you must reuse an old state).

Serving + online adaptation
---------------------------
The serving surface (``src/repro/serve/``) is a session-style
continuous-batching engine plus the paper's FD machinery re-used as
serve-time telemetry and an online learner:

  * ``Engine.submit(Request) -> handle`` / ``Engine.step()`` /
    ``Engine.drain()`` — each batch lane decodes at its own sequence
    position; a short request frees its lane the step it finishes and the
    next queued request prefills into the wiped slot.  Per-request
    ``max_new_tokens`` and ``temperature`` are honored per lane.  (The old
    one-shot ``Engine.generate`` survives as a deprecated wrapper — see
    the CHANGES.md migration table.)
  * ``GradientMonitor`` (serve/monitor.py) — a per-window FD sketch of the
    live feedback gradients; at each window boundary it reads the leading
    eigenvalue, the escaped-mass pressure ``rho/(trace+rho)``, and the
    drift angle vs the previous window's sketch subspace, then decides
    steady / adapt / pause (pause = suspected bad traffic).
  * ``OnlineAdapter`` (serve/adapt.py) — the S-AdaGrad OCO step over the
    flattened head, built through ``inject_hyperparams`` so
    ``adapter.set_hyperparams(learning_rate=..., beta2=...)`` mutates the
    live values with no retrace.

Driven end-to-end by ``python -m repro.launch.serve --traffic ... --adapt
... --monitor ...`` (deterministic constant/step load shapes from
serve/loadgen.py; the ``serve_latency_*`` benchmark rows come from the
same loop).  ``main()`` below runs a small submit/step/drain session and
one monitored adaptation window.
"""
import collections

import jax
import jax.numpy as jnp

from repro.configs.registry import get_reduced
from repro.core import api
from repro.core.factory import OptimizerConfig, make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as model_lib
from repro.train.trainer import make_train_step


def state_summary(opt_state) -> str:
    """Bytes per StateMeta role — works for any optimizer on the engine."""
    by_role = collections.Counter()
    for meta, leaf in api.leaves_with_meta(opt_state):
        role = meta.role if meta is not None else "untagged"
        by_role[role] += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
    return "  ".join(f"{r}={b/1e3:.1f}kB" for r, b in sorted(by_role.items()))


def main():
    cfg = get_reduced("paper_lm_100m")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} (reduced) — {n_params / 1e6:.2f}M params")

    # The paper's optimizer: FD-sketched Shampoo, rank 256 (rank 8 here for
    # the tiny demo). Second-moment memory is O((m+n)*rank) per block.
    # schedule="constant" keeps the lr a stored state value => mutable below.
    tx = make_optimizer(OptimizerConfig(
        name="sketchy", learning_rate=5e-3, rank=8, block_size=32,
        update_every=2, total_steps=50, schedule="constant"))

    opt_state = tx.init(params)
    print("optimizer state by role:", state_summary(opt_state))
    fp32_bytes = api.second_moment_bytes(opt_state)
    print(f"second-moment bytes (paper Fig. 1 quantity): {fp32_bytes}")

    # memory knob: the same state stored int8 between steps (compute stays
    # f32; eval_shape => no arrays materialized for the comparison)
    tx_int8 = make_optimizer(OptimizerConfig(
        name="sketchy", learning_rate=5e-3, rank=8, block_size=32,
        update_every=2, total_steps=50, schedule="constant",
        second_moment_dtype="int8"))
    int8_bytes = api.second_moment_bytes(jax.eval_shape(tx_int8.init, params))
    print(f"second-moment bytes with second_moment_dtype='int8': "
          f"{int8_bytes} ({fp32_bytes / int8_bytes:.1f}x smaller)")

    # rank-budget introspection: per-pool active sketch ranks (for this
    # static config every block sits at the ladder capacity; under
    # rank_budget=RankBudget(policy="rho_greedy") the same call shows the
    # budget migrating toward high-rho blocks while the bytes above stay
    # fixed at max_k capacity)
    alloc = api.rank_allocation(opt_state)
    print(f"rank allocation (total K = {alloc['total']}):")
    for key, grp in alloc["groups"].items():
        ks = grp["k"]
        share = 100.0 * float(grp["budget_share"].sum())
        print(f"  pool {key}: {len(ks)} blocks, k={ks.min()}..{ks.max()}, "
              f"{share:.0f}% of budget, mean rho {grp['rho'].mean():.2e}")

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8))
    step = make_train_step(cfg, tx)  # jitted + donated internally

    for t in range(50):
        if t == 30:  # runtime schedule change: decay lr 5x, no chain rebuild
            opt_state = api.set_hyperparams(opt_state, learning_rate=1e-3)
            print(f"step {t:3d}  lr ->",
                  float(api.get_hyperparams(opt_state)["learning_rate"]))
        batch = {k: jnp.asarray(v) for k, v in data.batch(t).items()}
        params, opt_state, m = step(params, opt_state, batch)
        if t % 10 == 0 or t == 49:
            print(f"step {t:3d}  loss {float(m['loss']):.4f}")

    # --- serving + online adaptation (serve/) ------------------------------
    import numpy as np

    from repro.serve import (AdaptConfig, Engine, GradientMonitor,
                             MonitorConfig, OnlineAdapter, Request,
                             ServeConfig)

    engine = Engine(cfg, params, ServeConfig(batch=2, max_seq=32))
    rng = np.random.default_rng(0)
    handles = [engine.submit(Request(
        prompt=rng.integers(0, cfg.vocab_size, size=(6,), dtype=np.int32),
        max_new_tokens=n)) for n in (4, 7, 5)]   # 3 requests, 2 lanes
    engine.drain()                               # slot reuse serves all 3
    for h in handles:
        print(f"served request {h.id}: {len(h.tokens)} tokens "
              f"(lane claimed at step {h.start_step})")

    # feedback batches -> FD monitor -> S-AdaGrad head adaptation
    adapter = OnlineAdapter(cfg, params, AdaptConfig(lr=0.1, beta2=0.95))
    monitor = GradientMonitor(adapter.d, MonitorConfig(window=3, top_k=3))
    for t in range(3):
        fb = {k: jnp.asarray(v) for k, v in data.batch(100 + t).items()}
        loss, g = adapter.grad(params, fb)
        reading = monitor.observe(g)             # closes the window at t=2
    print("monitor:", reading)
    params, loss = adapter.step(params, fb)
    engine.params = params                       # serve the adapted head
    adapter.set_hyperparams(learning_rate=0.02)  # runtime knob, no retrace
    print(f"adapted head, feedback loss {float(loss):.4f}, "
          f"lr -> {adapter.hyperparams['learning_rate']:.3f}")


if __name__ == "__main__":
    main()
