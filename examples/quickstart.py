"""Quickstart: Sketchy (S-Shampoo) as a drop-in optimizer on a tiny LM.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_reduced
from repro.core.factory import OptimizerConfig, make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as model_lib
from repro.train.trainer import make_train_step


def main():
    cfg = get_reduced("paper_lm_100m")
    print(f"model: {cfg.name} (reduced) — "
          f"{sum(x.size for x in jax.tree.leaves(model_lib.init_params(cfg, jax.random.PRNGKey(0)))) / 1e6:.2f}M params")

    # The paper's optimizer: FD-sketched Shampoo, rank 256 (rank 8 here for
    # the tiny demo). Second-moment memory is O((m+n)*rank) per block.
    tx = make_optimizer(OptimizerConfig(
        name="sketchy", learning_rate=5e-3, rank=8, block_size=32,
        update_every=2, total_steps=50, schedule="constant"))

    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = tx.init(params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8))
    step = jax.jit(make_train_step(cfg, tx))

    for t in range(50):
        batch = {k: jnp.asarray(v) for k, v in data.batch(t).items()}
        params, opt_state, m = step(params, opt_state, batch)
        if t % 10 == 0 or t == 49:
            print(f"step {t:3d}  loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    main()
