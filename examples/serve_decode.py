"""Serving example: continuous-batching greedy decode with KV cache.

    PYTHONPATH=src python examples/serve_decode.py

Runs the one-shot submit/drain demo of ``repro.launch.serve``.  Extra args
pass through — e.g. add load-generator traffic with FD monitoring and
online adaptation:

    PYTHONPATH=src python examples/serve_decode.py \\
        --traffic shape=step,rate=1,ticks=16,step_at=8 \\
        --monitor window=4 --adapt lr=0.1
"""
import sys

from repro.launch import serve


def main():
    sys.argv = [sys.argv[0], "--arch", "paper-lm-100m", "--batch", "4",
                "--max-seq", "48", "--new-tokens", "10"] + sys.argv[1:]
    serve.main()


if __name__ == "__main__":
    main()
