"""Batched serving example: prefill + greedy decode with KV cache.

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys

from repro.launch import serve


def main():
    sys.argv = [sys.argv[0], "--arch", "paper-lm-100m", "--batch", "4",
                "--max-seq", "48", "--new-tokens", "10"] + sys.argv[1:]
    serve.main()


if __name__ == "__main__":
    main()
