"""End-to-end driver: train the ~100M paper LM for a few hundred steps with
checkpoint/restart. Thin wrapper over the production launcher.

Full-size (slow on CPU; the real target is TPU):
    PYTHONPATH=src python examples/train_lm.py --steps 300
CPU-quick:
    PYTHONPATH=src python examples/train_lm.py --reduced --steps 100
"""
import sys

from repro.launch import train


def main():
    argv = sys.argv[1:]
    defaults = ["--arch", "paper-lm-100m", "--optimizer", "sketchy",
                "--batch", "8", "--seq", "256", "--lr", "3e-3",
                "--checkpoint-dir", "/tmp/repro-ckpt-train-lm", "--resume",
                "--metrics-out", "experiments/train_lm_metrics.json"]
    sys.argv = [sys.argv[0]] + defaults + argv
    train.main()


if __name__ == "__main__":
    main()
