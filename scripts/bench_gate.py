#!/usr/bin/env python
"""Benchmark-regression gate for CI.

Compares a fresh ``benchmarks/run.py --json`` output against the committed
``benchmarks/baseline.json`` and fails (exit 1) when a gated row regresses:

  * ``fig1_memory_*`` — the paper's headline quantity.  Gated on the byte
    count parsed from the derived column; ANY increase is a regression
    (memory accounting is exact, not noisy).
  * ``bytes_on_wire_per_refresh`` — the distributed-FD merge wire cost
    (sketch_merge.pack_wire structures); byte-exact like the memory rows,
    ANY increase is a regression.
  * ``opt_step_time_*``, ``serve_latency_*``, ``monitor_overhead_*`` —
    wall-time rows.  Gated on ``us_per_call`` with a multiplicative
    tolerance (default 1.75x) because shared CI runners are noisy; tighten
    locally with ``--time-tolerance``.
  * ``opt_overhead_vs_adam`` — the sketchy/adam step-cost ratio parsed from
    ``ratio=<x>x`` in the derived column.  Unitless, so runner speed cancels
    out; gated with the same multiplicative tolerance as the time rows.

``--only memory`` gates just the byte-exact rows (fig1_memory_*,
bytes_on_wire_*) — CI runs these as a BLOCKING step; ``--only time`` gates
just the wall-time rows (non-blocking on shared runners); the default
``--only all`` gates both.

Rows present in only one of the two files are reported but not fatal — the
benchmark set grows PR over PR and the baseline is refreshed when it does.

Usage:
  python benchmarks/run.py --json /tmp/bench.json
  python scripts/bench_gate.py /tmp/bench.json \
      [--baseline benchmarks/baseline.json] [--time-tolerance 1.75] \
      [--only memory|time|all]
"""
from __future__ import annotations

import argparse
import json
import re
import sys

_BYTES = re.compile(r"^(\d+)B\b")
_RATIO = re.compile(r"\bratio=([\d.]+)x")


def _rows(path: str) -> dict:
    with open(path) as f:
        return {r["name"]: r for r in json.load(f)}


def _bytes_of(row: dict):
    m = _BYTES.match(row.get("derived", ""))
    return int(m.group(1)) if m else None


def _ratio_of(row: dict):
    m = _RATIO.search(row.get("derived", ""))
    return float(m.group(1)) if m else None


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("fresh", help="JSON from `benchmarks/run.py --json`")
    p.add_argument("--baseline", default="benchmarks/baseline.json")
    p.add_argument("--time-tolerance", type=float, default=1.75,
                   help="max allowed us_per_call ratio vs baseline for "
                        "opt_step_time_* rows")
    p.add_argument("--only", choices=("memory", "time", "all"), default="all",
                   help="gate only the byte-exact rows (memory), only the "
                        "wall-time rows (time), or both (all)")
    args = p.parse_args(argv)

    base = _rows(args.baseline)
    fresh = _rows(args.fresh)
    gate_mem = args.only in ("memory", "all")
    gate_time = args.only in ("time", "all")

    failures, notes = [], []
    for name in sorted(set(base) | set(fresh)):
        if name not in fresh:
            notes.append(f"row {name!r} missing from fresh run")
            continue
        if name not in base:
            notes.append(f"new row {name!r} (not in baseline)")
            continue
        b, f = base[name], fresh[name]
        is_bytes_row = name.startswith("fig1_memory_") or \
            name.startswith("bytes_on_wire")
        if is_bytes_row and gate_mem:
            bb, fb = _bytes_of(b), _bytes_of(f)
            if bb is None or fb is None:
                failures.append(f"{name}: unparseable bytes "
                                f"({b['derived']!r} vs {f['derived']!r})")
            elif fb > bb:
                failures.append(
                    f"{name}: gated bytes regressed {bb} -> {fb}")
        elif name == "opt_overhead_vs_adam" and gate_time:
            br, fr = _ratio_of(b), _ratio_of(f)
            if br is None or fr is None:
                failures.append(f"{name}: unparseable ratio "
                                f"({b['derived']!r} vs {f['derived']!r})")
            elif fr > br * args.time_tolerance:
                failures.append(
                    f"{name}: sketchy/adam ratio regressed {br:.2f}x -> "
                    f"{fr:.2f}x (> {args.time_tolerance}x tolerance)")
        elif name.startswith(("opt_step_time", "serve_latency",
                              "monitor_overhead")) and gate_time:
            ratio = f["us_per_call"] / max(b["us_per_call"], 1e-9)
            if ratio > args.time_tolerance:
                failures.append(
                    f"{name}: {f['us_per_call']:.1f}us vs baseline "
                    f"{b['us_per_call']:.1f}us ({ratio:.2f}x > "
                    f"{args.time_tolerance}x)")

    for n in notes:
        print(f"note: {n}")
    if failures:
        print(f"\nBENCH GATE FAILED ({len(failures)} regressions):")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    print(f"bench gate OK: {len(set(base) & set(fresh))} rows compared, "
          "no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
