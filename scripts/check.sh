#!/usr/bin/env bash
# Smoke target: tier-1 tests + the fast memory/FD benchmarks.
#   scripts/check.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pytest -x -q "$@"

echo "--- fast benchmarks (fig1 memory + lemma-1 FD error) ---"
PYTHONPATH=src python - <<'PY'
import sys
sys.path.insert(0, "benchmarks")
import run
print("name,us_per_call,derived")
run.bench_fig1_memory()
run.bench_lem1_fd_error()
PY
