#!/usr/bin/env bash
# Smoke targets.
#   scripts/check.sh [extra pytest args...]   full tier-1 + fast benchmarks
#                                             (runs the kernels tier first)
#   scripts/check.sh fast [extra pytest args] unit tests minus the slow
#                                             trainer/distributed suites
#   scripts/check.sh kernels [extra args]     batched Pallas kernels
#                                             (interpret mode) vs refs,
#                                             backend registry, and the
#                                             pool-parity pins
#   scripts/check.sh quant [extra args]       quantized second-moment pools
#                                             (fp32 parity, int8/bf16,
#                                             cross-dtype checkpoints)
#   scripts/check.sh async [extra args]       async refresh pipeline:
#                                             step-shifted parity matrix
#                                             first (schedule x dtype x
#                                             reduction), then donation +
#                                             checkpoint droppability
#   scripts/check.sh tune [extra args]        kernel autotuner: candidate-
#                                             sweep parity vs XLA refs,
#                                             cache modes + round-trip,
#                                             fused-epilogue jaxpr pins,
#                                             then the committed fixture's
#                                             schema validation
#   scripts/check.sh budget [extra args]      rank-budget allocator: the
#                                             static-policy bitwise-parity
#                                             matrix first, then allocator
#                                             properties, rho_greedy
#                                             migration, and checkpoint
#                                             migration
#   scripts/check.sh serve [extra args]       serving stack: continuous-
#                                             batching parity vs the old
#                                             static path, slot reuse, FD
#                                             gradient monitor policy,
#                                             set_hyperparams no-retrace,
#                                             and the e2e shift-adapt
#                                             scenario
# Extra pytest args reach EVERY pytest invocation of the chosen tier,
# including the kernels tier that the full tier runs first.
# All tiers run a compileall syntax gate first so breakage surfaces before
# pytest collection.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "--- syntax gate (python -m compileall src) ---"
python -m compileall -q src

kernels_tier() {
  # interpret-mode kernel sweeps + registry dispatch + the bitwise
  # pool-parity pins against tests/reference_impls.py
  python -m pytest -x -q \
    tests/test_kernels.py \
    tests/test_kernel_registry.py \
    tests/test_pool.py::test_pooled_engine_bitwise_matches_per_leaf \
    "$@"
}

quant_tier() {
  # quantized pool storage: fp32 bitwise pin, int8 round-trip property
  # tests, bf16 convergence tolerance, cross-dtype checkpoint migration
  python -m pytest -x -q tests/test_quantize.py "$@"
}

async_tier() {
  # parity FIRST: the step-shifted-equality matrix is the correctness
  # contract of refresh_mode="async" — run it before the donation and
  # checkpoint plumbing so a parity break fails the tier immediately
  python -m pytest -x -q \
    tests/test_async_refresh.py::test_async_committed_equals_inline \
    tests/test_async_refresh.py::test_async_shampoo_parity \
    tests/test_async_refresh.py::test_async_parity_under_sharded_stats \
    "$@"
  python -m pytest -x -q \
    tests/test_async_refresh.py \
    tests/test_trainer.py::test_train_step_donates_buffers \
    --deselect tests/test_async_refresh.py::test_async_committed_equals_inline \
    --deselect tests/test_async_refresh.py::test_async_shampoo_parity \
    --deselect tests/test_async_refresh.py::test_async_parity_under_sharded_stats \
    "$@"
}

if [[ "${1:-}" == "kernels" ]]; then
  shift
  kernels_tier "$@"
  exit 0
fi

if [[ "${1:-}" == "quant" ]]; then
  shift
  quant_tier "$@"
  exit 0
fi

tune_tier() {
  # every tile candidate in the autotuner's menu must match the XLA refs
  # (hypothesis sweeps over ragged shapes/dtypes), cache modes and the
  # reload round-trip must be deterministic, and the fused int8 epilogue's
  # no-f32-materialization jaxpr pins must hold; finally the committed
  # fixture is schema-validated against the candidate space
  python -m pytest -x -q tests/test_autotune.py "$@"
  python -m repro.kernels.autotune validate
}

if [[ "${1:-}" == "async" ]]; then
  shift
  async_tier "$@"
  exit 0
fi

if [[ "${1:-}" == "tune" ]]; then
  shift
  tune_tier "$@"
  exit 0
fi

budget_tier() {
  # parity FIRST: RankBudget(policy="static") must stay bitwise-identical
  # to the pre-budget engine across the schedule x mode x dtype matrix —
  # a parity break fails the tier before the allocator property tests,
  # the rho_greedy migration checks, and the fixed-rank checkpoint shim
  python -m pytest -x -q \
    "tests/test_rank_budget.py::test_static_policy_bitwise_parity" \
    "$@"
  python -m pytest -x -q tests/test_rank_budget.py \
    --deselect tests/test_rank_budget.py::test_static_policy_bitwise_parity \
    "$@"
}

if [[ "${1:-}" == "budget" ]]; then
  shift
  budget_tier "$@"
  exit 0
fi

serve_tier() {
  # parity FIRST: the session API must reproduce the old static-batch
  # greedy tokens across cache families before the telemetry/adaptation
  # tests run — a decode regression fails the tier immediately
  python -m pytest -x -q \
    "tests/test_serve.py::test_continuous_batching_matches_static_batch" \
    "$@"
  python -m pytest -x -q tests/test_serve.py \
    --deselect tests/test_serve.py::test_continuous_batching_matches_static_batch \
    "$@"
}

if [[ "${1:-}" == "serve" ]]; then
  shift
  serve_tier "$@"
  exit 0
fi

if [[ "${1:-}" == "fast" ]]; then
  shift
  # unit tier: drops the trainer/distributed suites plus the two
  # multi-minute convergence sweeps (convex OCO regret, all-archs forward)
  python -m pytest -x -q \
    --ignore=tests/test_trainer.py \
    --ignore=tests/test_distributed.py \
    --ignore=tests/test_optim_convex.py \
    --ignore=tests/test_models.py \
    "$@"
  exit 0
fi

echo "--- kernels tier (batched Pallas vs refs + pool-parity pins) ---"
kernels_tier "$@"

# rest of tier-1; the kernels-tier files already ran above, skip re-running
# the interpret-mode Pallas sweeps (test_pool re-runs only its one pin)
python -m pytest -x -q \
  --ignore=tests/test_kernels.py \
  --ignore=tests/test_kernel_registry.py \
  "$@"

echo "--- fast benchmarks (fig1 memory + lemma-1 FD error) ---"
PYTHONPATH=src python - <<'PY'
import sys
sys.path.insert(0, "benchmarks")
import run
print("name,us_per_call,derived")
run.bench_fig1_memory()
run.bench_lem1_fd_error()
PY
