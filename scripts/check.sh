#!/usr/bin/env bash
# Smoke targets.
#   scripts/check.sh [extra pytest args...]   full tier-1 + fast benchmarks
#   scripts/check.sh fast [extra pytest args] unit tests minus the slow
#                                             trainer/distributed suites
# Both tiers run a compileall syntax gate first so breakage surfaces before
# pytest collection.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "--- syntax gate (python -m compileall src) ---"
python -m compileall -q src

if [[ "${1:-}" == "fast" ]]; then
  shift
  # unit tier: drops the trainer/distributed suites plus the two
  # multi-minute convergence sweeps (convex OCO regret, all-archs forward)
  python -m pytest -x -q \
    --ignore=tests/test_trainer.py \
    --ignore=tests/test_distributed.py \
    --ignore=tests/test_optim_convex.py \
    --ignore=tests/test_models.py \
    "$@"
  exit 0
fi

python -m pytest -x -q "$@"

echo "--- fast benchmarks (fig1 memory + lemma-1 FD error) ---"
PYTHONPATH=src python - <<'PY'
import sys
sys.path.insert(0, "benchmarks")
import run
print("name,us_per_call,derived")
run.bench_fig1_memory()
run.bench_lem1_fd_error()
PY
