"""Dry-run sweep driver: every (arch x shape) on single-pod (+probes) and
multi-pod (compile-proof). Resumable: skips cells with existing JSON."""
import json, os, subprocess, sys, time

ARCHS = ["gemma-2b", "phi3-mini-3.8b", "mamba2-370m", "musicgen-large",
         "paper-lm-100m", "deepseek-moe-16b", "zamba2-7b", "qwen2.5-32b",
         "qwen3-32b", "qwen2-vl-72b", "kimi-k2-1t-a32b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

def run(arch, shape, outdir, extra):
    out = f"experiments/dryrun/{outdir}/{arch}-{shape}.json"
    if os.path.exists(out):
        print(f"SKIP (exists) {outdir} {arch} {shape}", flush=True)
        return
    t0 = time.time()
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out] + extra
    r = subprocess.run(cmd, capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"}, timeout=7200)
    dt = time.time() - t0
    status = "OK" if r.returncode == 0 else f"FAIL({r.returncode})"
    print(f"{status} {outdir} {arch} {shape} {dt:.0f}s", flush=True)
    if r.returncode != 0:
        with open(out + ".err", "w") as f:
            f.write(r.stdout[-4000:] + "\n" + r.stderr[-8000:])

for arch in ARCHS:
    for shape in SHAPES:
        try:
            run(arch, shape, "pod", [])
        except Exception as e:
            print("ERR", arch, shape, e, flush=True)
for arch in ARCHS:
    for shape in SHAPES:
        try:
            run(arch, shape, "multipod", ["--multi-pod", "--skip-probes"])
        except Exception as e:
            print("ERR", arch, shape, e, flush=True)
print("SWEEP_DONE", flush=True)
