"""deepseek-moe-16b [moe] — fine-grained: 2 shared + 64 routed top-6,
first layer dense [arXiv:2401.06066; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, vocab_size=102400,
    num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, mlp_act="swiglu",
    num_experts=64, experts_per_token=6, num_shared_experts=2,
    first_dense_layers=1, dense_ff=10944,
    rope_theta=1e4,
)
