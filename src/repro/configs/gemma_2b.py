"""gemma-2b [dense] — GeGLU, head_dim=256, MQA (kv=1), tied embeddings,
embeds scaled by sqrt(d_model) [arXiv:2403.08295; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, vocab_size=256000,
    num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, mlp_act="geglu",
    rope_theta=1e4,
    tie_embeddings=True, embed_scale=True,
)
