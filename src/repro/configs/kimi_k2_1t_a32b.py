"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 routed experts top-8
+ 1 shared, first layer dense (paper-table config) [arXiv:2501.kimi2]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, vocab_size=163840,
    num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=2048, mlp_act="swiglu",
    num_experts=384, experts_per_token=8, num_shared_experts=1,
    first_dense_layers=1, dense_ff=18432,
    rope_theta=5e4,
)
