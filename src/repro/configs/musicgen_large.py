"""musicgen-large [audio] — decoder-only over EnCodec tokens, 4 parallel
codebooks (delay-pattern scheduling out of scope; frontend stubbed)
[arXiv:2306.05284; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, vocab_size=2048,
    num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, mlp_act="swiglu",
    num_codebooks=4,
    rope_theta=1e4,
)
