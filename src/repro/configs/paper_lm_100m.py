"""paper-lm-100m — ~100M-param dense LM for the end-to-end driver
(the paper's own benchmarks are vision/audio/graph; DESIGN.md §6)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-lm-100m", family="dense",
    num_layers=12, d_model=768, vocab_size=32768,
    num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, mlp_act="swiglu",
    rope_theta=1e4,
)
