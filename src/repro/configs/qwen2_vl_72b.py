"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Transformer BACKBONE only; the vision frontend is a stub (input_specs feeds
precomputed patch embeddings, per the assignment)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, vocab_size=152064,
    num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=29568, mlp_act="swiglu",
    qkv_bias=True,            # qwen2 family uses QKV bias
    mrope=True, rope_theta=1e6,
    embed_inputs=False,       # frontend stub: precomputed embeddings
)
