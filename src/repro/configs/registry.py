"""Architecture registry + assigned input shapes + dry-run input specs."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCHS = (
    "qwen2_vl_72b", "zamba2_7b", "qwen2_5_32b", "phi3_mini_3_8b", "gemma_2b",
    "qwen3_32b", "deepseek_moe_16b", "kimi_k2_1t_a32b", "musicgen_large",
    "mamba2_370m", "paper_lm_100m",
)

# public ids (assignment spelling) -> module names
ALIASES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "zamba2-7b": "zamba2_7b",
    "qwen2.5-32b": "qwen2_5_32b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "gemma-2b": "gemma_2b",
    "qwen3-32b": "qwen3_32b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "musicgen-large": "musicgen_large",
    "mamba2-370m": "mamba2_370m",
    "paper-lm-100m": "paper_lm_100m",
}


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    """Smoke-test variant: same family/features, tiny dims."""
    cfg = get_config(name)
    kv = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0
    heads = 4 if cfg.num_heads else 0
    if cfg.num_kv_heads == 1:
        kv = 1
    repl = dict(
        num_layers=max(2, min(3, cfg.num_layers)),
        d_model=64,
        vocab_size=256,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        dense_ff=128 if cfg.dense_ff else 0,
        num_experts=8 if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2),
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        attn_every=2 if cfg.attn_every else 0,
        capacity_factor=8.0,   # no token drops => decode == forward exactly
        q_chunk=32,
        remat=False,
        dtype="float32",
        first_dense_layers=min(cfg.first_dense_layers, 1),
    )
    if cfg.family == "hybrid":
        repl["num_layers"] = 4
    return dataclasses.replace(cfg, **repl)


def applicable_shapes(cfg: ModelConfig):
    """The assigned cells for this arch (long_500k only if sub-quadratic)."""
    for name, sh in SHAPES.items():
        if name == "long_500k" and not cfg.subquadratic:
            continue  # documented skip: full-attention arch (DESIGN.md §5)
        yield sh


def input_specs(cfg: ModelConfig, shape: ShapeCfg, *,
                batch_override: Optional[int] = None) -> dict:
    """ShapeDtypeStruct stand-ins for one step's inputs (no allocation)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)

    if shape.kind in ("train", "prefill"):
        specs = {}
        if cfg.embed_inputs:
            tok_shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
            specs["tokens"] = jax.ShapeDtypeStruct(tok_shape, i32)
        else:  # vlm stub: precomputed patch/frame embeddings
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
        if cfg.mrope:
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        if shape.kind == "train":
            lab_shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
            specs["labels"] = jax.ShapeDtypeStruct(lab_shape, i32)
        return specs

    # decode: single token against a length-S cache
    specs = {}
    if cfg.embed_inputs:
        tok_shape = (B, 1, cfg.num_codebooks) if cfg.num_codebooks else (B, 1)
        specs["token"] = jax.ShapeDtypeStruct(tok_shape, i32)
    else:
        specs["embed"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
    return specs
