"""zamba2-7b [hybrid] — Mamba2 backbone + ONE shared attention(+MLP) block
applied every 6 layers [arXiv:2411.15242; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, vocab_size=32000,
    num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, mlp_act="swiglu",
    ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    attn_every=6,
    tie_embeddings=True,
)
