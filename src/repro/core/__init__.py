"""Core: the paper's contribution — FD sketching + Sketchy optimizers.

The optimizer layer is built around the unified Preconditioner API
(core/api.py): one shared ``scale_by_preconditioner`` engine plus small
per-variant ``Preconditioner`` implementations, with ``StateMeta`` metadata
attached to every optimizer-state leaf.
"""
from repro.core.fd import FDState, fd_init, fd_update, fd_update_batched, \
    fd_covariance, fd_apply_inverse_root, fd_apply_inverse_root_batched, \
    fd_inverse_root_coeffs  # noqa: F401
from repro.core.api import (  # noqa: F401
    EngineConfig, InjectState, Preconditioner, PrecondState, StateMeta,
    Tagged, get_hyperparams, get_stage, inject_hyperparams, leaves_with_meta,
    map_with_meta, named_chain, pool_stats, scale_by_preconditioner,
    second_moment_bytes, set_hyperparams, tag, tag_like, untag)
from repro.core.pool import (  # noqa: F401
    LeafPlan, PoolGroup, PoolIndex, build_index, group_key)
from repro.core.sketchy import SketchyConfig, SketchyPreconditioner  # noqa: F401
from repro.core.shampoo import ShampooConfig, ShampooPreconditioner  # noqa: F401
from repro.core.adam import AdamConfig, AdamPreconditioner  # noqa: F401
from repro.core.factory import OptimizerConfig, make_optimizer  # noqa: F401
