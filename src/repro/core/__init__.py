"""Core: the paper's contribution — FD sketching + Sketchy optimizers."""
from repro.core.fd import FDState, fd_init, fd_update, fd_covariance, \
    fd_apply_inverse_root, fd_inverse_root_coeffs  # noqa: F401
from repro.core.sketchy import SketchyConfig  # noqa: F401
from repro.core.shampoo import ShampooConfig  # noqa: F401
from repro.core.adam import AdamConfig  # noqa: F401
from repro.core.factory import OptimizerConfig, make_optimizer  # noqa: F401
