"""Adam baseline (paper's first-order comparison) — linear-memory diag
second moment, expressed as a *diagonal* ``Preconditioner`` on the shared
engine (``diagonal=True``: each leaf is handled whole; blocking, grafting,
and cadence gating do not apply)."""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import api, blocking
from repro.core.transform import GradientTransformation


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    state_dtype: Any = jnp.float32


class AdamLeafStats(NamedTuple):
    mu: jnp.ndarray     # first moment (bias-corrected at apply time)
    nu: jnp.ndarray     # diag second moment


@dataclasses.dataclass(frozen=True)
class AdamPreconditioner:
    cfg: AdamConfig

    diagonal: ClassVar[bool] = True

    def init_block(self, info: blocking.BlockInfo) -> AdamLeafStats:
        # two distinct buffers: sharing one zeros array would be donated
        # twice by the trainer's donate_argnums=(0, 1) step
        return AdamLeafStats(
            mu=api.tag(jnp.zeros(info.shape, self.cfg.state_dtype),
                       "momentum"),
            nu=api.tag(jnp.zeros(info.shape, self.cfg.state_dtype),
                       "second_moment"))

    def update_stats(self, state, G, *, count):
        c = self.cfg
        return AdamLeafStats(
            mu=c.beta1 * state.mu + (1 - c.beta1) * G.astype(state.mu.dtype),
            nu=c.beta2 * state.nu
            + (1 - c.beta2) * jnp.square(G.astype(state.nu.dtype)))

    def refresh(self, state, G, *, count):
        return state

    def precondition(self, state, G, *, count):
        c = self.cfg
        t = (count + 1).astype(jnp.float32)
        bc1 = 1 - c.beta1 ** t
        bc2 = 1 - c.beta2 ** t
        return (state.mu / bc1) * jax.lax.rsqrt(state.nu / bc2 + c.eps ** 2)


def adam(cfg: AdamConfig = AdamConfig()) -> GradientTransformation:
    return api.scale_by_preconditioner(
        AdamPreconditioner(cfg),
        api.EngineConfig(graft="none", update_every=1,
                         state_dtype=cfg.state_dtype))


def second_moment_bytes(state) -> int:
    return api.second_moment_bytes(state)
