"""Adam baseline (paper's first-order comparison) — linear-memory diag
second moment."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.transform import GradientTransformation


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    state_dtype: Any = jnp.float32


class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def adam(cfg: AdamConfig = AdamConfig()) -> GradientTransformation:
    def init_fn(params):
        zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
        return AdamState(count=jnp.zeros([], jnp.int32),
                         mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def update_fn(updates, state, params=None):
        del params
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: cfg.beta1 * m + (1 - cfg.beta1) * g.astype(m.dtype),
                          state.mu, updates)
        nu = jax.tree.map(lambda v, g: cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g.astype(v.dtype)),
                          state.nu, updates)
        bc1 = 1 - cfg.beta1 ** count.astype(jnp.float32)
        bc2 = 1 - cfg.beta2 ** count.astype(jnp.float32)
        out = jax.tree.map(
            lambda m, v, g: ((m / bc1) * jax.lax.rsqrt(v / bc2 + cfg.eps ** 2)).astype(g.dtype),
            mu, nu, updates)
        return out, AdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init_fn, update_fn)


def second_moment_bytes(state: AdamState) -> int:
    return sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(state.nu))
