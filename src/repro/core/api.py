"""Unified Preconditioner API: one engine, metadata-driven state.

The paper frames Sketchy, Shampoo, and Adam as points on a single
memory/quality trade-off curve over the same Kronecker-factored second-moment
statistics.  This module makes that framing first-class:

  * ``Preconditioner`` — the protocol an optimizer variant implements.  It is
    deliberately tiny: per matrix *block*, how to initialize statistics, how
    to accumulate them every step, how to refresh the (expensive) derived
    preconditioner on a cadence, and how to apply it to a gradient block.

  * ``scale_by_preconditioner(precond, cfg)`` — the one shared engine.  It
    owns everything the per-optimizer monoliths used to duplicate: parameter
    blocking (paper §3.4), the diagonal fallback for vectors/scalars,
    grafting (App. C), and ``update_every`` / ``start_preconditioning_step``
    gating.  Execution is *pooled* (core/pool.py): every matrix block in the
    model is packed into one ``(N, bs_m, bs_n)`` stack per unique block
    shape, and the three Preconditioner methods run once per shape group —
    not once per parameter leaf — so a 400-leaf model compiles a handful of
    kernel sets and the pooled blocks dim spans the whole model for mesh
    sharding.  Refresh is either ``synchronized`` (all blocks on
    ``count % update_every == 0``, the parity default) or ``staggered``
    (per-block phase, ~N/update_every blocks per step — same amortized work
    with no global eigh spike).

  * ``StateMeta`` / ``Tagged`` — every engine state leaf is wrapped in a
    ``Tagged`` pytree node carrying a static ``StateMeta`` (role, blocked
    layout, owning-parameter index).  Downstream consumers — sharding
    assignment, checkpoint manifests, memory accounting — traverse this
    metadata instead of ``isinstance``-dispatching on optimizer-specific
    NamedTuples, so a new optimizer variant needs zero consumer changes.

  * ``named_chain`` / ``inject_hyperparams`` — labelled composition and
    hyperparameters-in-state, so serving/elastic re-mesh code can read or
    mutate e.g. the learning rate at runtime without rebuilding the chain.

``Tagged`` wraps exactly one array leaf.  It is transparent to single-tree
``jax.tree.map`` (the map recurses into it and reconstructs it, preserving
the metadata), to ``jax.vmap``/``jax.lax.cond`` (metadata is static aux
data), and to flattening (it contributes exactly one leaf, so flat orders
match the untagged tree).  When an implementation needs typed containers of
raw arrays (e.g. ``FDState``), the engine strips tags with ``untag`` before
compute and restores them with ``tag_like`` after.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import blocking, pool, quantize
from repro.core.transform import GradientTransformation
from repro.kernels import registry as kernel_registry

PyTree = Any

# Roles a state leaf can play.  second_moment is the paper's headline memory
# quantity (Fig. 1); preconditioner covers derived caches (e.g. Shampoo's
# inverse roots) that are excluded from it.
ROLES = ("second_moment", "preconditioner", "grafting", "momentum", "count",
         "hyperparam")


@dataclasses.dataclass(frozen=True)
class StateMeta:
    """Static annotation attached to one optimizer-state array leaf."""
    role: str
    blocked: bool = False          # leading axis is the stacked-blocks dim
    param_index: Optional[int] = None  # flat index of the owning parameter
    shard: str = "auto"            # auto | blocks | param | replicate
    # Transient leaves are re-derivable scratch (the async refresh pending
    # slot): excluded from ``second_moment_bytes`` (they never hold the only
    # copy of a statistic) and dropped by checkpoint save/restore
    # (train/checkpoint.py zero-fills them on load).
    transient: bool = False
    # Telemetry labels for metadata-driven read APIs (``rank_allocation``):
    # implementations mark e.g. the per-block active-rank vector
    # ("active_rank"), the escaped-mass scalar ("rho"), or the eigenvalue
    # ladder ("eigvals").  ``group`` is stamped by the engine with the
    # owning pool's group key at init.  Neither is persisted in checkpoint
    # manifests (restore templates re-derive them from code).
    label: Optional[str] = None
    group: Optional[str] = None

    def __post_init__(self):
        if self.role not in ROLES:
            raise ValueError(f"unknown state role {self.role!r}")


@jax.tree_util.register_pytree_with_keys_class
class Tagged:
    """Pytree node wrapping a single array leaf plus its static StateMeta."""
    __slots__ = ("value", "meta")

    def __init__(self, value, meta: StateMeta):
        self.value = value
        self.meta = meta

    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("value"), self.value),), self.meta

    @classmethod
    def tree_unflatten(cls, meta, children):
        return cls(children[0], meta)

    def __repr__(self):
        return f"Tagged({self.value!r}, {self.meta})"


def tag(value, role: str, **kw) -> Tagged:
    return Tagged(value, StateMeta(role=role, **kw))


def _is_tagged(x) -> bool:
    return isinstance(x, Tagged)


def untag(tree: PyTree) -> PyTree:
    """Strip Tagged wrappers, leaving a plain array pytree."""
    return jax.tree.map(lambda x: x.value if _is_tagged(x) else x, tree,
                        is_leaf=_is_tagged)


def tag_like(template: PyTree, values: PyTree) -> PyTree:
    """Re-attach ``template``'s tags onto a congruent untagged tree."""
    return jax.tree.map(
        lambda t, v: Tagged(v, t.meta) if _is_tagged(t) else v,
        template, values, is_leaf=_is_tagged)


def leaves_with_meta(tree: PyTree) -> list:
    """Flat ``[(StateMeta | None, leaf), ...]`` in ``jax.tree.leaves`` order.

    Tagged nodes contribute their meta; plain leaves get ``None``.  Because a
    Tagged node holds exactly one leaf, the ordering is identical to a full
    flatten of the same tree.
    """
    out = []
    for x in jax.tree.leaves(tree, is_leaf=_is_tagged):
        if _is_tagged(x):
            out.append((x.meta, x.value))
        else:
            out.append((None, x))
    return out


def map_with_meta(fn: Callable[[Optional[StateMeta], Any], Any],
                  tree: PyTree) -> PyTree:
    """Map ``fn(meta_or_None, leaf) -> leaf`` over a tree, keeping tags."""
    def one(x):
        if _is_tagged(x):
            return Tagged(fn(x.meta, x.value), x.meta)
        return fn(None, x)
    return jax.tree.map(one, tree, is_leaf=_is_tagged)


def second_moment_bytes(state: PyTree) -> int:
    """Second-moment memory by metadata traversal — the paper's Fig. 1
    quantity (excludes grafting/momentum/derived preconditioners).  Works on
    any state pytree: a bare engine state, a named chain, a full injected
    optimizer state, or shape structs from ``jax.eval_shape``.

    Transient leaves (the async-refresh pending slot) are excluded: they
    double-buffer statistics already counted in the live pools, so counting
    them again would report the paper's Fig. 1 quantity double."""
    total = 0
    for meta, leaf in leaves_with_meta(state):
        if meta is not None and meta.role == "second_moment" \
                and not meta.transient:
            total += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
    return total


def rank_allocation(state: PyTree) -> dict:
    """Per-block sketch-rank allocation by metadata traversal — the read
    API of the rank-budget allocator, mirroring ``second_moment_bytes``:
    works on any state pytree (bare engine state, named chain, injected
    optimizer state) with no isinstance-dispatch on optimizer containers.

    Returns ``{"total": K, "groups": {group_key: {"k", "rho",
    "budget_share"}}}`` with per-block (N,) arrays per pool group:
    ``k`` the active ranks (for static engines, the ladder capacity —
    every block at the configured rank), ``rho`` the per-block escaped
    mass summed over sketch sides, and ``budget_share = k / K``.  On
    ``jax.eval_shape`` structs the rank vector falls back to capacity and
    ``rho`` to zeros (shapes carry no values).
    """
    import numpy as np

    concrete = lambda x: not isinstance(x, jax.ShapeDtypeStruct)
    per: dict = {}
    for meta, leaf in leaves_with_meta(state):
        if meta is None or meta.transient or meta.group is None \
                or meta.label is None:
            continue
        g = per.setdefault(meta.group, {"k": None, "rho": [], "ladder": []})
        if meta.label == "active_rank":
            g["k"] = leaf
        elif meta.label == "rho":
            g["rho"].append(leaf)
        elif meta.label == "eigvals":
            g["ladder"].append(leaf)
    if not per:
        raise ValueError("no sketch state found (state carries no labelled "
                         "StateMeta leaves)")

    ks = {}
    for key, g in sorted(per.items()):
        if g["k"] is not None and concrete(g["k"]):
            ks[key] = np.asarray(g["k"], dtype=np.int64)
        else:
            # static engine (or shape structs): active rank == ladder
            # capacity, i.e. the configured rank clipped per side — report
            # the wider side
            n = g["ladder"][0].shape[0] if g["ladder"] \
                else g["k"].shape[0]
            cap = max((l.shape[-1] for l in g["ladder"]), default=0)
            ks[key] = np.full((n,), cap, dtype=np.int64)
    total = int(sum(int(k.sum()) for k in ks.values()))

    groups = {}
    for key, g in sorted(per.items()):
        k = ks[key]
        rho_leaves = [r for r in g["rho"] if concrete(r)]
        rho = (np.sum([np.asarray(r, np.float64) for r in rho_leaves],
                      axis=0)
               if rho_leaves else np.zeros(k.shape, np.float64))
        groups[key] = {"k": k, "rho": rho,
                       "budget_share": k / max(total, 1)}
    return {"total": total, "groups": groups}


# ---------------------------------------------------------------------------
# Preconditioner protocol


@runtime_checkable
class Preconditioner(Protocol):
    """One optimizer variant = one small implementation of this protocol.

    ``diagonal = False`` (kron-style: sketchy, shampoo, sadagrad): the engine
    blocks each matrix leaf into a ``(S, bm, bn)`` stack and vmaps the three
    methods over blocks; vector/scalar leaves take the engine's shared
    diagonal (RMSProp) fallback.

    ``diagonal = True`` (adam): every leaf is handled whole by the
    implementation's own elementwise logic; blocking, grafting, and gating
    are skipped.

    Engine call order per step (mirrors the seed monoliths exactly):
      state = update_stats(state, G)        # every step (cheap accumulation)
      state = refresh(state, G)             # every cfg.update_every steps
      P     = precondition(state, G)        # every step (apply)

    Batched execution: the engine dispatches each method once per pooled
    ``(N, bs_m, bs_n)`` shape group.  An implementation may provide
    ``update_stats_batched`` / ``refresh_batched`` / ``precondition_batched``
    taking the whole stacked state + gradient stack — sketchy and shampoo do,
    routing the hot contractions through the grid-over-N batched kernels of
    their injected ``KernelSet`` — in which case the engine calls the batched
    entry point directly (no vmap).  Without them the engine falls back to
    ``jax.vmap`` of the per-block method, so minimal implementations keep
    working unchanged.

    Kernel injection: implementations that declare a ``kernels`` dataclass
    field (default ``None``) receive the engine's resolved ``KernelSet``
    (``EngineConfig.kernel_backend``) at transform-build time — one knob
    selects the backend uniformly for every kron-style optimizer.
    """
    diagonal: bool

    def init_block(self, info: blocking.BlockInfo) -> PyTree:
        """State for ONE block (Tagged leaves). The engine broadcasts it over
        the leaf's block stack."""
        ...

    def update_stats(self, state: PyTree, G: jnp.ndarray, *,
                     count: jnp.ndarray) -> PyTree:
        ...

    def refresh(self, state: PyTree, G: jnp.ndarray, *,
                count: jnp.ndarray) -> PyTree:
        ...

    def precondition(self, state: PyTree, G: jnp.ndarray, *,
                     count: jnp.ndarray) -> jnp.ndarray:
        ...


REFRESH_SCHEDULES = ("synchronized", "staggered")
STATS_REDUCTIONS = ("replicated", "sharded")
REFRESH_MODES = ("inline", "async")
QUANTIZED_EPILOGUES = ("auto", "off", "on")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything the shared engine owns (formerly duplicated per optimizer)."""
    block_size: int = 1024
    beta2: float = 0.999            # diag-fallback / grafting EMA decay
    update_every: int = 10          # refresh cadence (paper §6)
    start_preconditioning_step: int = 0
    graft: str = "rmsprop_normalized"   # rmsprop_normalized | rmsprop | none
    graft_eps: float = 1e-8
    # Diagonal-fallback damping for vector/scalar leaves.  None keeps the
    # historical coupling to graft_eps (bitwise parity with the seed).
    diag_eps: Optional[float] = None
    # synchronized: all blocks refresh on count % update_every == 0 (parity
    # default).  staggered: one synchronized warm refresh at count 0, then
    # block b refreshes when (count + b) % update_every == 0 — each block
    # exactly once per window, ~N/update_every eighs every step instead of N
    # on spike steps.
    refresh_schedule: str = "synchronized"
    # Kernel backend for the pooled matrix hot path: "pallas" (grid-over-N
    # batched kernels; interpret mode off-TPU), "xla" (pure-jnp batched
    # refs), or "auto" (pallas on TPU, xla elsewhere; REPRO_KERNEL_BACKEND
    # overrides the platform default).  Resolved once at transform build.
    kernel_backend: str = "auto"
    # Storage dtype for the pooled second-moment stacks BETWEEN steps
    # (core/quantize.py): "fp32" (identity, bitwise parity), "bf16" (2x), or
    # "int8" (per-block symmetric quantization of the matrix factors, ~4x).
    # By default compute dequantizes to f32 at the batched-method boundary,
    # so kernels and Preconditioner implementations never see quantized
    # arrays; see ``quantized_epilogue`` for the fused exception.
    second_moment_dtype: str = "fp32"
    # Fused int8 compute: hand the batched methods the QuantizedPool
    # containers themselves (quantize.compute_view) instead of dequantizing
    # the big factor stacks at the boundary — the implementation's batched
    # methods dispatch to fused kernels that upcast int8 in-registers and
    # re-quantize refreshed factors in-kernel, so the f32 stack never
    # materializes in HBM.  "auto": on iff second_moment_dtype is int8, the
    # resolved backend is pallas, the implementation opts in
    # (``supports_quantized_compute``), and stats are replicated (the
    # sharded merge needs f32 factors on the wire).  "off": always
    # dequantize (the PR-4 behaviour).  "on": force the fused path on any
    # backend (the xla refs implement the same fused entries — used by the
    # CPU parity tests).
    quantized_epilogue: str = "auto"
    # Second-moment maintenance across data-parallel shards
    # (src/repro/distributed/):
    #   "replicated" — every shard sees the dp-mean gradients and maintains
    #     identical statistics (the parity default).
    #   "sharded"    — each shard FD-updates on its *local* gradients
    #     (scaled 1/sqrt(P)) and refreshes end in a log-depth butterfly
    #     sketch merge over ``stats_axis``.  Requires the Preconditioner to
    #     implement ``refresh_sharded_batched`` (sketchy does); otherwise —
    #     or when ``stats_axis`` is unbound or 1-sized at trace time — the
    #     engine falls back to the replicated path bitwise.
    stats_reduction: str = "replicated"
    stats_axis: str = "data"
    # When the refresh lands relative to the step that triggered it:
    #   "inline" — the refreshed statistics precondition the SAME step's
    #     gradient (the parity default, bitwise-pinned to the references).
    #   "async"  — the refresh for step t's cohort is *launched* at t into a
    #     double-buffered pending slot (``PrecondState.pending``) and
    #     *committed* at t+1: the parameter update at t preconditions with
    #     the pre-refresh (one-step-stale) statistics, so the eigh and the
    #     butterfly merge rounds have no data dependency on the update
    #     direction and XLA is free to overlap them with the next step's
    #     forward/backward.  The committed statistics at step t+1 equal
    #     inline's at step t exactly (step-shifted parity, including int8
    #     storage: the pending slot is quantized with the step-t keys).
    refresh_mode: str = "inline"
    # Cross-pool rank-budget reallocation cadence, in refresh windows: every
    # ``realloc_every * update_every`` steps the engine hands ALL refreshed
    # pool stacks to the implementation's ``realloc_pools(groups, stacks)``
    # hook (rank-budget allocator, core/sketchy.py) right after the refresh
    # and before precondition/requantize.  0 (default) disables the hook —
    # the engine loop is then exactly the pre-budget one.  Under
    # ``refresh_mode="async"`` the reallocation rides the pending-slot
    # refresh and commits at t+1 with it (step-shifted parity preserved).
    realloc_every: int = 0
    # Emit jax.named_scope + jax.profiler.TraceAnnotation spans around the
    # engine's update_stats / refresh-launch / commit / precondition phases
    # so the refresh leaving the critical path is visible in a device trace.
    profile_annotations: bool = False
    state_dtype: Any = jnp.float32
    # OCO learners (S-AdaGrad, Alg. 2) precondition a d-vector with a full
    # d x d sketch: treat 1-D leaves as a single (d, 1) matrix block instead
    # of the diagonal fallback.
    treat_vectors_as_columns: bool = False

    def __post_init__(self):
        if self.refresh_schedule not in REFRESH_SCHEDULES:
            raise ValueError(
                f"unknown refresh_schedule {self.refresh_schedule!r}; "
                f"expected one of {REFRESH_SCHEDULES}")
        if self.kernel_backend not in kernel_registry.BACKENDS:
            raise ValueError(
                f"unknown kernel_backend {self.kernel_backend!r}; "
                f"expected one of {kernel_registry.BACKENDS}")
        if self.second_moment_dtype not in quantize.SECOND_MOMENT_DTYPES:
            raise ValueError(
                f"unknown second_moment_dtype {self.second_moment_dtype!r}; "
                f"expected one of {quantize.SECOND_MOMENT_DTYPES}")
        if self.stats_reduction not in STATS_REDUCTIONS:
            raise ValueError(
                f"unknown stats_reduction {self.stats_reduction!r}; "
                f"expected one of {STATS_REDUCTIONS}")
        if self.refresh_mode not in REFRESH_MODES:
            raise ValueError(
                f"unknown refresh_mode {self.refresh_mode!r}; "
                f"expected one of {REFRESH_MODES}")
        if self.quantized_epilogue not in QUANTIZED_EPILOGUES:
            raise ValueError(
                f"unknown quantized_epilogue {self.quantized_epilogue!r}; "
                f"expected one of {QUANTIZED_EPILOGUES}")
        if self.realloc_every < 0:
            raise ValueError(
                f"realloc_every must be >= 0, got {self.realloc_every}")


class LeafState(NamedTuple):
    """Per-leaf residue that cannot be pooled: param-shaped diagonal stats
    (diag fallback / diagonal preconditioners) and grafting accumulators.
    Pooled matrix leaves carry ``stats=None`` — their block statistics live
    in ``PrecondState.pools``."""
    stats: Any          # implementation-defined, Tagged leaves, or None
    graft: Any          # Tagged grafting accumulator, or None


class PendingSlot(NamedTuple):
    """One shape group's in-flight refresh (``refresh_mode="async"``): the
    refreshed stats stack launched at step t, in storage layout (same
    quantized structure as ``PrecondState.pools[key]``, tags marked
    ``transient``), plus a one-bit valid flag.  ``valid=False`` — the init
    state, or a checkpoint restore that dropped the slot — makes the commit
    a no-op and the engine falls back to the on-schedule refresh."""
    stats: Any          # storage-layout stack, transient StateMeta tags
    valid: Tagged       # bool scalar, role="count", transient


class PrecondState(NamedTuple):
    """Engine state: one packed stats stack per unique block shape (keyed by
    ``pool.group_key``; leading dim spans every matrix block in the model)
    plus the per-leaf residue.  ``pending`` is ``None`` under
    ``refresh_mode="inline"`` (contributing no pytree leaves, so inline
    checkpoints/manifests are unchanged) and a ``{group key: PendingSlot}``
    dict under ``"async"``."""
    count: Tagged
    pools: dict         # group key -> stats pytree (Tagged, leading dim N)
    leaves: tuple       # LeafState per flat param leaf
    pending: Any = None  # async refresh double-buffer, or None (inline)


def committed_pools(state: PrecondState) -> dict:
    """The storage-layout pools the NEXT update will precondition from.

    Inline mode: the live pools.  Async mode: each group's pending refresh
    committed over the live stack where its valid bit is set — exactly the
    select the engine performs at the top of the next step, so async state
    after step t satisfies ``committed_pools(async_t) == inline_t.pools``
    bitwise (the step-shifted parity contract)."""
    if state.pending is None:
        return state.pools
    out = {}
    for key, live in state.pools.items():
        slot = state.pending[key]
        out[key] = tag_like(live, pool.commit_select(
            slot.valid.value, untag(slot.stats), untag(live)))
    return out


def pool_stats(state: PrecondState, key: Optional[str] = None) -> Any:
    """Untagged f32 stats stack for one pool group (default: the only
    group).  Quantized storage (core/quantize.py) is dequantized, so callers
    always see the compute-layout tree regardless of second_moment_dtype."""
    if key is None:
        if len(state.pools) != 1:
            raise ValueError(
                f"state has {len(state.pools)} pools {sorted(state.pools)}; "
                "pass an explicit key")
        key = next(iter(state.pools))
    return quantize.dequantize_pool(state.pools[key])


def graft_direction(g: jnp.ndarray, acc: jnp.ndarray, *, graft: str,
                    beta2, graft_eps: float):
    """Grafting direction + updated accumulator (paper App. C,
    RMSPROP_NORMALIZED). ``g``/``acc`` are float32."""
    if graft == "none":
        return g, acc
    if graft == "rmsprop_normalized":
        gn = g / (jnp.linalg.norm(g) + 1e-16)
    else:
        gn = g
    acc = beta2 * acc + (1.0 - beta2) * jnp.square(gn)
    return gn * jax.lax.rsqrt(acc + graft_eps), acc


def _inject_kernels(precond: "Preconditioner",
                    kernels: kernel_registry.KernelSet) -> "Preconditioner":
    """Hand the engine's resolved KernelSet to implementations that want it.

    Any dataclass Preconditioner declaring a ``kernels`` field (sketchy,
    shampoo) gets the set injected — unless the caller already supplied one
    explicitly, which wins.  Everything else passes through untouched.
    """
    if dataclasses.is_dataclass(precond) and not isinstance(precond, type):
        names = {f.name for f in dataclasses.fields(precond)}
        if "kernels" in names and getattr(precond, "kernels") is None:
            return dataclasses.replace(precond, kernels=kernels)
    return precond


def _batched_method(precond: "Preconditioner", name: str):
    """``fn(stacked_state, G_stack, count)`` for one Preconditioner method.

    Prefers the implementation's ``<name>_batched`` (single call over the
    whole packed pool stack — the kernel-backed hot path); falls back to
    ``jax.vmap`` of the per-block method for minimal implementations.
    """
    batched = getattr(precond, name + "_batched", None)
    if batched is not None:
        return lambda s, G, count: batched(s, G, count=count)
    per_block = getattr(precond, name)
    return lambda s, G, count: jax.vmap(
        lambda ss, GG: per_block(ss, GG, count=count))(s, G)


def _stamp_group(tree: PyTree, key: str) -> PyTree:
    """Copy of a tagged tree with every StateMeta stamped with its pool
    group key — what lets ``rank_allocation`` bucket leaves per pool
    without touching optimizer-specific containers."""
    def one(x):
        if _is_tagged(x):
            return Tagged(x.value, dataclasses.replace(x.meta, group=key))
        return x
    return jax.tree.map(one, tree, is_leaf=_is_tagged)


def _mark_transient(tree: PyTree) -> PyTree:
    """Copy of a tagged tree with every StateMeta marked ``transient`` — the
    pending-slot layout: same structure/sharding as the live pools, excluded
    from memory accounting and checkpoints."""
    def one(x):
        if _is_tagged(x):
            return Tagged(x.value,
                          dataclasses.replace(x.meta, transient=True))
        return x
    return jax.tree.map(one, tree, is_leaf=_is_tagged)


@contextlib.contextmanager
def _span(name: str, enabled: bool):
    """Profiling span: a ``jax.named_scope`` (HLO op metadata — shows up in
    device traces, zero runtime cost) plus a ``jax.profiler.TraceAnnotation``
    (host-side trace event).  Disabled => pure passthrough."""
    if not enabled:
        yield
        return
    with jax.named_scope(name), jax.profiler.TraceAnnotation(name):
        yield


def _index_unblocked(tree: PyTree, i: int) -> PyTree:
    """Record the owning-param index on param-shaped (non-blocked) tags."""
    def one(x):
        if _is_tagged(x) and not x.meta.blocked and x.meta.param_index is None:
            return Tagged(x.value, dataclasses.replace(x.meta, param_index=i))
        return x
    return jax.tree.map(one, tree, is_leaf=_is_tagged)


def scale_by_preconditioner(precond: Preconditioner,
                            cfg: EngineConfig = EngineConfig()
                            ) -> GradientTransformation:
    """The ONE shared direction engine (emits a descent direction, no lr).

    Matrix blocks execute *pooled*: ``core/pool.py`` groups every block in
    the model by block shape and the three Preconditioner methods run once
    per shape group over a packed ``(N, bs_m, bs_n)`` stack — via the
    implementation's ``*_batched`` entry points (batched grid-over-N kernels
    from the ``cfg.kernel_backend`` KernelSet) when it has them, else a vmap
    fallback.  Only the per-leaf residue (diag fallback, grafting norms,
    gating) stays leafwise.
    """
    diag_eps = cfg.graft_eps if cfg.diag_eps is None else cfg.diag_eps
    qdtype = cfg.second_moment_dtype
    precond = _inject_kernels(precond,
                              kernel_registry.get_kernels(cfg.kernel_backend))
    # Fused int8 compute resolution (build time, like the backend itself):
    # the batched methods receive quantize.compute_view (containers kept)
    # instead of quantize.dequantize_pool (f32 at the boundary).
    fused_q = (
        qdtype == "int8"
        and cfg.quantized_epilogue != "off"
        and getattr(precond, "supports_quantized_compute", False)
        and cfg.stats_reduction != "sharded"
        and (cfg.quantized_epilogue == "on"
             or kernel_registry.resolve_backend(cfg.kernel_backend)
             == "pallas"))
    pool_compute = quantize.compute_view if fused_q \
        else quantize.dequantize_pool
    update_stats_b = _batched_method(precond, "update_stats")
    refresh_b = _batched_method(precond, "refresh")
    precondition_b = _batched_method(precond, "precondition")
    refresh_sharded_b = getattr(precond, "refresh_sharded_batched", None)

    def sharded_ctx():
        """(reduce module, axis size) when the sharded-stats path is live.

        Live means: the knob is on, the implementation can merge
        (``refresh_sharded_batched``), and ``cfg.stats_axis`` is bound with
        size > 1 at trace time.  Anything else returns (None, 1) and the
        engine takes the replicated path — bitwise-identical to the
        default, which is also what makes ``"sharded"`` on a 1-sized data
        axis exactly equal to ``"replicated"`` (a merge with one
        participant is the identity).
        """
        if cfg.stats_reduction != "sharded" or refresh_sharded_b is None:
            return None, 1
        from repro.distributed import reduce as dreduce
        size = dreduce.bound_axis_size(cfg.stats_axis)
        if size is None or size <= 1:
            return None, 1
        return dreduce, size

    def index_of(shapes) -> pool.PoolIndex:
        return pool.build_index(
            tuple(tuple(s) for s in shapes), cfg.block_size,
            vectors_as_columns=cfg.treat_vectors_as_columns)

    def init_fn(params):
        flat = jax.tree.leaves(params)
        count = tag(jnp.zeros([], jnp.int32), "count")
        if precond.diagonal:
            leaves = tuple(
                LeafState(stats=_index_unblocked(precond.init_block(
                    blocking.BlockInfo(kind="diag", shape=tuple(p.shape))), i),
                    graft=None)
                for i, p in enumerate(flat))
            return PrecondState(count=count, pools={}, leaves=leaves)

        index = index_of([p.shape for p in flat])
        stacks = {}
        for grp in index.groups:
            base = precond.init_block(grp.info)
            stacks[grp.key] = jax.tree.map(
                lambda x, n=grp.num_blocks:
                    jnp.broadcast_to(x, (n,) + x.shape), base)
        # cross-pool init hook (rank-budget allocator): the implementation
        # sees every broadcast stack at once — the first point where the
        # total block count (and so the resolved budget) is known
        finalize = getattr(precond, "finalize_init_pools", None)
        if finalize is not None:
            stacks = finalize(index.groups, stacks)
        pools = {}
        for grp in index.groups:
            # storage layout: quantized between steps (deterministic at init
            # — the stats are zeros/identity, nothing to dither)
            pools[grp.key] = _stamp_group(
                quantize.quantize_pool(stacks[grp.key], qdtype), grp.key)
        leaves = []
        for i, (p, plan) in enumerate(zip(flat, index.leaves)):
            if plan.group is None:
                # diag-fallback accumulator; stored quantized like the
                # pools (deterministic at init — zeros)
                leaves.append(LeafState(
                    stats=quantize.quantize_leaf_state(
                        tag(jnp.zeros(p.shape, cfg.state_dtype),
                            "second_moment", param_index=i), qdtype),
                    graft=None))
            else:
                graft = None
                if cfg.graft != "none":
                    graft = tag(jnp.zeros(p.shape, cfg.state_dtype),
                                "grafting", param_index=i)
                leaves.append(LeafState(stats=None, graft=graft))
        pending = None
        if cfg.refresh_mode == "async":
            # double buffer: same storage layout (and therefore sharding)
            # as the live pools, transient tags => not counted, not saved.
            # Fresh zero arrays, NOT references to the live pool buffers —
            # donated opt_state must not contain the same buffer twice.
            pending = {
                key: PendingSlot(
                    stats=_mark_transient(jax.tree.map(jnp.zeros_like, stack)),
                    valid=Tagged(jnp.zeros([], bool),
                                 StateMeta(role="count", transient=True)))
                for key, stack in pools.items()}
        return PrecondState(count=count, pools=pools, leaves=tuple(leaves),
                            pending=pending)

    def refresh_group(grp: pool.PoolGroup, raw, gb, count, vrefresh):
        """Gated refresh over one packed stack (raw = untagged stats);
        ``vrefresh(stats, G_stack)`` is the ungated refresh — the plain
        batched method, or its sharded-merge variant."""
        if cfg.update_every <= 1:
            return vrefresh(raw, gb)
        if cfg.refresh_schedule == "synchronized":
            return jax.lax.cond((count % cfg.update_every) == 0,
                                lambda s: vrefresh(s, gb), lambda s: s, raw)
        # staggered: block b is due when (count + b) % update_every == 0 —
        # at most ceil(N/k) blocks per step.  Gather the due blocks into a
        # fixed-capacity sub-stack, refresh only those, scatter back.  Fill
        # slots use the out-of-range index N: gathers clamp (the dummy
        # refresh result is discarded) and scatters drop, so no valid block
        # is ever clobbered.
        N, k = grp.num_blocks, cfg.update_every
        cap = -(-N // k)

        def staggered(s):
            due = (count + pool.block_ids(grp)) % k == 0
            idx = jnp.nonzero(due, size=cap, fill_value=N)[0]
            sub = vrefresh(jax.tree.map(lambda x: x[idx], s), gb[idx])
            return jax.tree.map(lambda x, ns: x.at[idx].set(ns), s, sub)

        # Cold start: off-phase blocks must not precondition with their
        # zero-initialized stats for up to k-1 steps, so count 0 does one
        # synchronized warm refresh (exactly what the synchronized schedule's
        # first step costs); phased refresh takes over from count 1.
        return jax.lax.cond(count == 0, lambda s: vrefresh(s, gb),
                            staggered, raw)

    def update_fn(updates, state, params=None):
        del params
        flat, treedef = jax.tree.flatten(updates)
        count = state.count.value
        new_count = Tagged(count + 1, state.count.meta)

        if precond.diagonal:
            out, new_leaves = [], []
            for g, leaf in zip(flat, state.leaves):
                g32 = g.astype(jnp.float32)
                raw = untag(leaf.stats)
                raw = precond.update_stats(raw, g32, count=count)
                direction = precond.precondition(raw, g32, count=count)
                out.append(direction.astype(g.dtype))
                new_leaves.append(LeafState(stats=tag_like(leaf.stats, raw),
                                            graft=None))
            return (jax.tree.unflatten(treedef, out),
                    PrecondState(count=new_count, pools={},
                                 leaves=tuple(new_leaves)))

        index = index_of([g.shape for g in flat])
        g32 = [g.astype(jnp.float32) for g in flat]

        # Sharded statistics (src/repro/distributed/): the direction /
        # grafting path keeps consuming dp-MEAN gradients, while the stats
        # path sees this shard's LOCAL gradients scaled 1/sqrt(P) (so the
        # butterfly-merged sketch estimates (1/P) sum_i G_i G_i^T — the
        # covariance of the mean-gradient stream the replicated path
        # sketches when shards agree).  The trainer hands the locals over
        # via ``distributed.reduce.local_gradients``; called without that
        # context, ``updates`` themselves are taken as local and the mean
        # is recovered with a pmean.
        dreduce, axis_size = sharded_ctx()
        g32_local = g32
        if dreduce is not None:
            ctx = dreduce.current_local_gradients()
            if ctx is None:
                g32 = [dreduce.pmean(g, cfg.stats_axis) for g in g32_local]
            else:
                g32_local = [g.astype(jnp.float32)
                             for g in jax.tree.leaves(ctx)]
        packed = pool.pack(index, g32)
        packed_stats = packed
        if dreduce is not None:
            inv_sqrt_p = axis_size ** -0.5
            packed_stats = pool.pack(index,
                                     [g * inv_sqrt_p for g in g32_local])

        # One update/refresh/precondition dispatch per SHAPE GROUP — the
        # whole model's same-shaped blocks in one batched call each, straight
        # into the implementation's batched (kernel-backed) entry points.
        # Pools are stored quantized (cfg.second_moment_dtype) between steps:
        # dequantize to f32 at this boundary, requantize the result.  For
        # fp32 both transforms are exactly untag/tag_like (bitwise parity).
        qkey = None
        if qdtype == "int8":
            # stochastic requantization keyed by step: unbiased across the
            # repeated quantize-accumulate cycle of the EMA statistics
            qkey = jax.random.fold_in(jax.random.PRNGKey(0x0517), count)
        if dreduce is None:
            vrefresh = lambda s, G: refresh_b(s, G, count)
        else:
            vrefresh = lambda s, G: refresh_sharded_b(
                s, G, count=count, axis=cfg.stats_axis, axis_size=axis_size)
        is_async = cfg.refresh_mode == "async" and state.pending is not None
        spans = cfg.profile_annotations
        realloc_fn = getattr(precond, "realloc_pools", None)
        do_realloc = (cfg.realloc_every > 0 and realloc_fn is not None
                      and len(index.groups) > 0)

        def gkey_of(gi):
            return None if qkey is None else jax.random.fold_in(qkey, gi)

        def maybe_realloc(raws):
            """Gated cross-pool rank-budget reallocation over ALL refreshed
            stacks at once (the budget is global, so the hook must see every
            pool): a no-op unless the implementation opts in via
            ``realloc_pools`` and ``cfg.realloc_every > 0``."""
            if not do_realloc:
                return raws
            period = max(cfg.update_every, 1) * cfg.realloc_every
            return jax.lax.cond(
                ((count % period) == 0) & (count > 0),
                lambda r: realloc_fn(index.groups, r), lambda r: r, raws)

        new_pools, pooled_dirs = {}, {}
        new_pending = {} if is_async else None
        if not is_async:
            # pass 1: accumulate + (gated) refresh every pool stack
            raws = {}
            for grp in index.groups:
                gb_stats = packed_stats[grp.key]
                raw = pool_compute(state.pools[grp.key])
                with _span("precond/update_stats", spans):
                    raw = update_stats_b(raw, gb_stats, count)
                with _span("precond/refresh", spans):
                    raws[grp.key] = refresh_group(grp, raw, gb_stats, count,
                                                  vrefresh)
            raws = maybe_realloc(raws)
            # pass 2: precondition + requantize from the (possibly
            # reallocated) refreshed stacks.  With realloc off this computes
            # exactly what the former single fused loop did, value for value.
            for gi, grp in enumerate(index.groups):
                raw = raws[grp.key]
                with _span("precond/precondition", spans):
                    pooled_dirs[grp.key] = precondition_b(
                        raw, packed[grp.key], count)
                new_pools[grp.key] = quantize.requantize_pool(
                    state.pools[grp.key], raw, key=gkey_of(gi))
        else:
            # async one-step-stale pipeline.  Per step t:
            #   1. commit: fold the refresh launched at t-1 (pending slot)
            #      over the live stack — a cheap elementwise select in
            #      storage layout, no eigh on this path;
            #   2. accumulate this step's statistics on the committed stack;
            #   3. precondition with those PRE-refresh stats — the update
            #      direction has no data dependency on this step's refresh,
            #      so the eigh + merge rounds below are free to overlap with
            #      the next step's forward/backward;
            #   4. launch: run the (gated) refresh into the pending slot,
            #      committed at t+1.
            # The commit therefore lands exactly what inline computed at t-1
            # (same refresh, same quantization keys), one step later.
            raws_pre, refreshed = {}, {}
            for grp in index.groups:
                gb_stats = packed_stats[grp.key]
                slot = state.pending[grp.key]
                live = state.pools[grp.key]
                with _span("precond/commit", spans):
                    committed = tag_like(live, pool.commit_select(
                        slot.valid.value, untag(slot.stats), untag(live)))
                raw = pool_compute(committed)
                with _span("precond/update_stats", spans):
                    raw = update_stats_b(raw, gb_stats, count)
                with _span("precond/precondition", spans):
                    pooled_dirs[grp.key] = precondition_b(
                        raw, packed[grp.key], count)
                with _span("precond/refresh_launch", spans):
                    refreshed[grp.key] = refresh_group(grp, raw, gb_stats,
                                                       count, vrefresh)
                raws_pre[grp.key] = raw
            # reallocation rides the refresh pipeline: it lands in the
            # pending slot and commits at t+1 together with the refresh, so
            # the step-shifted parity contract is preserved
            refreshed = maybe_realloc(refreshed)
            for gi, grp in enumerate(index.groups):
                slot = state.pending[grp.key]
                live = state.pools[grp.key]
                gkey = gkey_of(gi)
                # live stack stores the pre-refresh stats, pending the
                # refreshed ones — both under the step-t quantization keys,
                # so whichever side the next commit selects is bitwise what
                # inline stored
                new_pools[grp.key] = quantize.requantize_pool(
                    live, raws_pre[grp.key], key=gkey)
                new_pending[grp.key] = PendingSlot(
                    stats=quantize.requantize_pool(slot.stats,
                                                   refreshed[grp.key],
                                                   key=gkey),
                    valid=Tagged(jnp.ones([], bool), slot.valid.meta))

        # Per-leaf residue: diag fallback, grafting norms, gating.
        out, new_leaves = [], []
        for i, (g, leaf, plan) in enumerate(zip(flat, state.leaves,
                                                index.leaves)):
            gi = g32[i]
            if plan.group is None:   # diagonal (RMSProp) fallback
                # storage may be quantized (satellite of the pool-level
                # scheme): dequantize/requantize are exact pass-throughs
                # for fp32 (bitwise parity)
                if dreduce is None:
                    sq = jnp.square(gi)
                else:
                    # the diag residue travels in the sharded reduction
                    # too: mean of per-shard squares over the data axis
                    sq = dreduce.pmean(jnp.square(g32_local[i]),
                                       cfg.stats_axis)
                acc = cfg.beta2 * quantize.dequantize_pool(leaf.stats) \
                    + (1.0 - cfg.beta2) * sq
                direction = gi * jax.lax.rsqrt(acc + diag_eps)
                out.append(direction.astype(g.dtype))
                lkey = None if qkey is None \
                    else jax.random.fold_in(qkey, len(index.groups) + i)
                new_leaves.append(LeafState(
                    stats=quantize.requantize_pool(leaf.stats, acc,
                                                   key=lkey), graft=None))
                continue

            direction = pool.unpack_leaf(index, pooled_dirs, i)
            if cfg.graft != "none":
                graft_dir, new_acc = graft_direction(
                    gi, leaf.graft.value, graft=cfg.graft, beta2=cfg.beta2,
                    graft_eps=cfg.graft_eps)
                pnorm = jnp.linalg.norm(direction)
                gnorm = jnp.linalg.norm(graft_dir)
                direction = direction * (gnorm / (pnorm + 1e-16))
                new_graft = Tagged(new_acc, leaf.graft.meta)
            else:
                graft_dir = gi
                new_graft = None

            if cfg.start_preconditioning_step > 0:
                use_precond = count >= cfg.start_preconditioning_step
                direction = jnp.where(use_precond, direction, graft_dir)
            out.append(direction.astype(g.dtype))
            new_leaves.append(LeafState(stats=None, graft=new_graft))

        return (jax.tree.unflatten(treedef, out),
                PrecondState(count=new_count, pools=new_pools,
                             leaves=tuple(new_leaves),
                             pending=new_pending))

    return GradientTransformation(init_fn, update_fn)


# ---------------------------------------------------------------------------
# Labelled composition + hyperparameters-in-state


def named_chain(*stages) -> GradientTransformation:
    """``chain`` with labelled stages: state is ``{name: member_state}``.

    Stage names become checkpoint-manifest path components and are the lookup
    key for ``get_stage`` — no positional index guessing.
    """
    names = [n for n, _ in stages]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate stage names: {names}")

    def init_fn(params):
        return {name: t.init(params) for name, t in stages}

    def update_fn(updates, state, params=None):
        new_state = {}
        for name, t in stages:
            updates, new_state[name] = t.update(updates, state[name], params)
        return updates, new_state

    return GradientTransformation(init_fn, update_fn)


class InjectState(NamedTuple):
    count: Tagged
    hyperparams: dict    # name -> Tagged scalar (role 'hyperparam')
    inner: Any


def inject_hyperparams(inner_factory: Callable[..., GradientTransformation]):
    """optax-style wrapper: numeric hyperparameters live in optimizer state.

    ``inner_factory(**hypers)`` must build a GradientTransformation whose
    *state structure* does not depend on the hyperparameter values.  Each
    declared hyper is either a number (stored in state, mutable at runtime
    via ``set_hyperparams`` — no chain rebuild) or a callable schedule
    ``count -> value`` (re-evaluated every step from the injected count; the
    current value is still mirrored into state for observability).
    """
    def make(**hypers):
        def resolve(count, current: dict) -> dict:
            out = {}
            for k, v in hypers.items():
                if callable(v):
                    out[k] = jnp.asarray(v(count), jnp.float32)
                else:
                    out[k] = current[k]
            return out

        def init_fn(params):
            count0 = jnp.zeros([], jnp.int32)
            vals = {k: jnp.asarray(v(count0) if callable(v) else v,
                                   jnp.float32)
                    for k, v in hypers.items()}
            inner = inner_factory(**vals).init(params)
            return InjectState(
                count=tag(count0, "count"),
                hyperparams={k: tag(v, "hyperparam")
                             for k, v in vals.items()},
                inner=inner)

        def update_fn(updates, state, params=None):
            count = state.count.value
            current = {k: t.value for k, t in state.hyperparams.items()}
            vals = resolve(count, current)
            tx = inner_factory(**vals)
            updates, inner = tx.update(updates, state.inner, params)
            return updates, InjectState(
                count=Tagged(count + 1, state.count.meta),
                hyperparams={k: Tagged(v, state.hyperparams[k].meta)
                             for k, v in vals.items()},
                inner=inner)

        return GradientTransformation(init_fn, update_fn)

    return make


def set_hyperparams(state: InjectState, **overrides) -> InjectState:
    """Mutate stored hyperparameter values at runtime (serve/elastic) without
    rebuilding the chain.  Schedule-driven hypers are recomputed from the
    step count each update; overriding those here only affects the mirrored
    value until the next step."""
    hp = dict(state.hyperparams)
    for k, v in overrides.items():
        if k not in hp:
            raise KeyError(f"unknown hyperparameter {k!r}; have {list(hp)}")
        t = hp[k]
        hp[k] = Tagged(jnp.asarray(v, t.value.dtype), t.meta)
    return state._replace(hyperparams=hp)


def get_hyperparams(state: InjectState) -> dict:
    return {k: t.value for k, t in state.hyperparams.items()}


def get_stage(state, name: str):
    """Fetch a named stage's state from a (possibly injected) chain state."""
    if isinstance(state, InjectState):
        return get_stage(state.inner, name)
    if isinstance(state, dict):
        if name not in state:
            raise KeyError(f"no stage {name!r}; have {sorted(state)}")
        return state[name]
    raise TypeError(f"not a named-chain state: {type(state)}")
