"""Shampoo-style parameter blocking (paper §3.4, "Blocked Shampoo").

Every parameter tensor is normalized to a *stack of matrix blocks*:

  - scalars / vectors        -> 'diag' (no Kronecker factors; diagonal path)
  - (..., m, n) tensors      -> leading dims flattened into a stack dim
                                 (scan-over-layers stacks, MoE expert dims),
                                 last two dims tiled into blocks of at most
                                 ``block_size`` (padded to equal tiles so the
                                 whole thing is vmap-able).

Blocking bounds the Kronecker-factor size (the paper fixes 1024) and is what
makes the FD sketch rank ``ell`` meaningful per-block.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BlockInfo:
    kind: str              # 'diag' | 'matrix'
    shape: tuple           # original shape
    stack: int = 1         # flattened leading dims
    m: int = 0             # original matrix rows
    n: int = 0             # original matrix cols
    bs_m: int = 0          # block rows
    bs_n: int = 0          # block cols
    mb: int = 0            # number of row tiles
    nb: int = 0            # number of col tiles

    @property
    def num_blocks(self) -> int:
        return self.stack * self.mb * self.nb

    @property
    def block_shape(self) -> tuple:
        """(bs_m, bs_n) — the pool-grouping key (core/pool.py)."""
        return (self.bs_m, self.bs_n)


def _tile(dim: int, block_size: int) -> tuple[int, int]:
    """(num_tiles, tile_size) with tile_size <= block_size; padded layout."""
    if dim <= block_size:
        return 1, dim
    nt = math.ceil(dim / block_size)
    return nt, block_size


def analyze(shape: tuple, block_size: int = 1024) -> BlockInfo:
    if len(shape) < 2 or min(shape[-2:]) == 1:
        return BlockInfo(kind="diag", shape=tuple(shape))
    *lead, m, n = shape
    stack = int(math.prod(lead)) if lead else 1
    mb, bs_m = _tile(m, block_size)
    nb, bs_n = _tile(n, block_size)
    return BlockInfo(kind="matrix", shape=tuple(shape), stack=stack,
                     m=m, n=n, bs_m=bs_m, bs_n=bs_n, mb=mb, nb=nb)


def analyze_leaf(shape: tuple, block_size: int = 1024, *,
                 vectors_as_columns: bool = False) -> BlockInfo:
    """``analyze`` plus the OCO convention: with ``vectors_as_columns`` a 1-D
    leaf becomes a single (d, 1) matrix block (S-AdaGrad preconditions the
    whole d-vector with one full sketch, Alg. 2) instead of the diagonal
    fallback."""
    if vectors_as_columns and len(shape) == 1 and shape[0] >= 1:
        mb, bs_m = _tile(shape[0], block_size)
        return BlockInfo(kind="matrix", shape=tuple(shape), stack=1,
                         m=shape[0], n=1, bs_m=bs_m, bs_n=1, mb=mb, nb=1)
    return analyze(tuple(shape), block_size)


def to_blocks(x: jnp.ndarray, info: BlockInfo) -> jnp.ndarray:
    """(..., m, n) -> (stack*mb*nb, bs_m, bs_n), zero-padded."""
    assert info.kind == "matrix"
    x = x.reshape(info.stack, info.m, info.n)
    pm = info.mb * info.bs_m - info.m
    pn = info.nb * info.bs_n - info.n
    if pm or pn:
        x = jnp.pad(x, ((0, 0), (0, pm), (0, pn)))
    x = x.reshape(info.stack, info.mb, info.bs_m, info.nb, info.bs_n)
    x = x.transpose(0, 1, 3, 2, 4)
    return x.reshape(info.num_blocks, info.bs_m, info.bs_n)


def from_blocks(blocks: jnp.ndarray, info: BlockInfo) -> jnp.ndarray:
    """Inverse of to_blocks, dropping padding."""
    assert info.kind == "matrix"
    x = blocks.reshape(info.stack, info.mb, info.nb, info.bs_m, info.bs_n)
    x = x.transpose(0, 1, 3, 2, 4)
    x = x.reshape(info.stack, info.mb * info.bs_m, info.nb * info.bs_n)
    x = x[:, :info.m, :info.n]
    return x.reshape(info.shape)
