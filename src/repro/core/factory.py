"""Optimizer factory: name -> full training transformation chain.

Chain layout (paper App. C conventions), as a labelled ``named_chain``:
  clip -> precond (sketchy | shampoo | adam direction)
  -> momentum (EMA "moving_average_for_momentum") -> weight_decay
  -> lr (negated schedule)

The whole chain is wrapped in ``inject_hyperparams`` so ``learning_rate`` and
``beta2`` live in optimizer state: serve/elastic code can read or mutate them
at runtime (``api.set_hyperparams``) without rebuilding the chain.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import api
from repro.core import adam as adam_lib
from repro.core import shampoo as shampoo_lib
from repro.core import sketchy as sketchy_lib
from repro.core import schedules, transform


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "sketchy"              # sketchy | shampoo | adam
    learning_rate: float = 1e-3
    total_steps: int = 1000
    warmup_frac: float = 0.05
    beta1: float = 0.9
    beta2: float = 0.999
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0
    schedule: str = "warmup_cosine"    # warmup_cosine | constant
    # sketchy/shampoo specific
    rank: int = 256
    # Sketch-rank budget (sketchy only; see core/sketchy.RankBudget): a
    # fixed total rank shared across all pooled blocks plus the per-block
    # allocation policy.  None keeps the uniform static allocation at
    # ``rank`` (exactly the pre-budget behavior); a RankBudget supersedes
    # ``rank`` for the direction stage.
    rank_budget: Optional[sketchy_lib.RankBudget] = None
    block_size: int = 1024
    update_every: int = 10
    start_preconditioning_step: int = 0
    # kernel backend for the pooled matrix hot path (kernels/registry.py):
    # "pallas" | "xla" | "auto" (pallas on TPU, xla elsewhere;
    # REPRO_KERNEL_BACKEND env overrides the platform default).  Replaces
    # the old sketchy-private use_kernels flag; applies to shampoo too.
    kernel_backend: str = "auto"
    # refresh phasing over the pooled block stacks (core/pool.py):
    # synchronized reproduces the seed exactly; staggered spreads the eigh
    # cost uniformly (one 1/update_every slice of blocks per step).
    refresh_schedule: str = "synchronized"
    # when the refresh lands (core/api.py): "inline" (same step, parity
    # default) | "async" (launched at t into a double-buffered pending
    # slot, committed at t+1 — eigh + merge leave the step's critical path)
    refresh_mode: str = "inline"
    # profiling spans around the engine's update/refresh/precondition
    # phases (jax.named_scope + profiler.TraceAnnotation)
    profile_annotations: bool = False
    # diagonal-fallback damping for vector/scalar leaves; None keeps the
    # historical graft_eps coupling (seed parity).
    diag_eps: Optional[float] = None
    # storage dtype for pooled second-moment stacks between steps
    # (core/quantize.py): "fp32" (bitwise parity) | "bf16" (2x) | "int8"
    # (per-block symmetric quantization of the matrix factors, ~4x).
    # Applies to sketchy and shampoo; adam's elementwise state is untouched.
    second_moment_dtype: str = "fp32"
    # fused int8 compute (core/api.py quantized_epilogue): "auto" | "off" |
    # "on" — sketchy only (shampoo's root solve needs f32 factors)
    quantized_epilogue: str = "auto"
    # Second-moment maintenance across data-parallel shards
    # (src/repro/distributed/): "replicated" keeps every replica's
    # statistics identical from dp-mean gradients (parity default);
    # "sharded" has each shard FD-update on its local gradients and merge
    # sketches in a log-depth butterfly at refresh time.  Only sketchy
    # implements the merge (``refresh_sharded_batched``) — shampoo/adam
    # fall back to replicated statistics under this knob.
    stats_reduction: str = "replicated"


def _direction(cfg: OptimizerConfig, beta2) -> transform.GradientTransformation:
    if cfg.name == "sketchy":
        # construct the budget explicitly (the deprecated rank= spelling
        # would warn on every step — _direction runs inside the injected
        # chain's update)
        budget = cfg.rank_budget if cfg.rank_budget is not None \
            else sketchy_lib.RankBudget(min_k=cfg.rank, max_k=cfg.rank,
                                        policy="static")
        return sketchy_lib.sketchy(sketchy_lib.SketchyConfig(
            rank_budget=budget, block_size=cfg.block_size, beta2=beta2,
            update_every=cfg.update_every,
            start_preconditioning_step=cfg.start_preconditioning_step,
            refresh_schedule=cfg.refresh_schedule,
            refresh_mode=cfg.refresh_mode,
            profile_annotations=cfg.profile_annotations,
            diag_eps=cfg.diag_eps,
            kernel_backend=cfg.kernel_backend,
            second_moment_dtype=cfg.second_moment_dtype,
            quantized_epilogue=cfg.quantized_epilogue,
            stats_reduction=cfg.stats_reduction))
    if cfg.name == "shampoo":
        return shampoo_lib.shampoo(shampoo_lib.ShampooConfig(
            block_size=cfg.block_size, beta2=beta2,
            root_every=cfg.update_every,
            start_preconditioning_step=cfg.start_preconditioning_step,
            refresh_schedule=cfg.refresh_schedule,
            refresh_mode=cfg.refresh_mode,
            profile_annotations=cfg.profile_annotations,
            diag_eps=cfg.diag_eps,
            kernel_backend=cfg.kernel_backend,
            second_moment_dtype=cfg.second_moment_dtype))
    if cfg.name == "adam":
        return adam_lib.adam(adam_lib.AdamConfig(
            beta1=cfg.beta1, beta2=beta2))
    raise ValueError(f"unknown optimizer {cfg.name!r}")


def make_optimizer(cfg: OptimizerConfig) -> transform.GradientTransformation:
    def build(learning_rate, beta2):
        stages = []
        if cfg.grad_clip:
            stages.append(("clip", transform.clip_by_global_norm(cfg.grad_clip)))
        stages.append(("precond", _direction(cfg, beta2)))
        if cfg.name != "adam":  # adam has built-in beta1 momentum
            stages.append(("momentum", transform.momentum(cfg.beta1, ema=True)))
        if cfg.weight_decay:
            stages.append(("weight_decay",
                           transform.add_decayed_weights(cfg.weight_decay)))
        stages.append(("lr", transform.scale(-1.0 * learning_rate)))
        return api.named_chain(*stages)

    if cfg.schedule == "warmup_cosine":
        lr_hyper = schedules.warmup_cosine(cfg.learning_rate, cfg.total_steps,
                                           cfg.warmup_frac)
    else:
        # constant lr is stored as a plain value => runtime-mutable via
        # api.set_hyperparams (serve-time schedule changes, elastic re-mesh)
        lr_hyper = cfg.learning_rate
    return api.inject_hyperparams(build)(learning_rate=lr_hyper,
                                         beta2=cfg.beta2)


def second_moment_bytes(state) -> int:
    """Second-moment memory of the direction stage, found by StateMeta
    traversal — works on any chain nesting, no type dispatch."""
    total = api.second_moment_bytes(state)
    if total == 0:
        raise ValueError("no second-moment state found (state carries no "
                         "StateMeta annotations)")
    return total
