"""Optimizer factory: name -> full training transformation chain.

Chain layout (paper App. C conventions):
  clip_by_global_norm -> direction (sketchy | shampoo | adam)
  -> EMA momentum ("moving_average_for_momentum") -> decoupled weight decay
  -> -lr(t) schedule
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core import adam as adam_lib
from repro.core import shampoo as shampoo_lib
from repro.core import sketchy as sketchy_lib
from repro.core import schedules, transform


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "sketchy"              # sketchy | shampoo | adam
    learning_rate: float = 1e-3
    total_steps: int = 1000
    warmup_frac: float = 0.05
    beta1: float = 0.9
    beta2: float = 0.999
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0
    schedule: str = "warmup_cosine"    # warmup_cosine | constant
    # sketchy/shampoo specific
    rank: int = 256
    block_size: int = 1024
    update_every: int = 10
    start_preconditioning_step: int = 0
    use_kernels: bool = False


def make_optimizer(cfg: OptimizerConfig) -> transform.GradientTransformation:
    if cfg.name == "sketchy":
        direction = sketchy_lib.sketchy(sketchy_lib.SketchyConfig(
            rank=cfg.rank, block_size=cfg.block_size, beta2=cfg.beta2,
            update_every=cfg.update_every,
            start_preconditioning_step=cfg.start_preconditioning_step,
            use_kernels=cfg.use_kernels))
    elif cfg.name == "shampoo":
        direction = shampoo_lib.shampoo(shampoo_lib.ShampooConfig(
            block_size=cfg.block_size, beta2=cfg.beta2,
            root_every=cfg.update_every,
            start_preconditioning_step=cfg.start_preconditioning_step))
    elif cfg.name == "adam":
        direction = adam_lib.adam(adam_lib.AdamConfig(
            beta1=cfg.beta1, beta2=cfg.beta2))
    else:
        raise ValueError(f"unknown optimizer {cfg.name!r}")

    if cfg.schedule == "warmup_cosine":
        sched = schedules.warmup_cosine(cfg.learning_rate, cfg.total_steps,
                                        cfg.warmup_frac)
    else:
        sched = schedules.constant(cfg.learning_rate)
    neg = lambda c: -sched(c)

    parts = []
    if cfg.grad_clip:
        parts.append(transform.clip_by_global_norm(cfg.grad_clip))
    parts.append(direction)
    if cfg.name != "adam":  # adam has built-in beta1 momentum
        parts.append(transform.momentum(cfg.beta1, ema=True))
    if cfg.weight_decay:
        parts.append(transform.add_decayed_weights(cfg.weight_decay))
    parts.append(transform.scale_by_schedule(neg))
    return transform.chain(*parts)


def second_moment_bytes(name: str, state) -> int:
    """Second-moment memory of the *direction* stage inside the chain."""
    idx = 1 if len(state) >= 2 and isinstance(state[0], tuple) and not state[0] else None
    # chain state: tuple of member states; find the direction stage by type.
    for s in state:
        if isinstance(s, sketchy_lib.SketchyState):
            return sketchy_lib.second_moment_bytes(s)
        if isinstance(s, shampoo_lib.ShampooState):
            return shampoo_lib.second_moment_bytes(s)
        if isinstance(s, adam_lib.AdamState):
            return adam_lib.second_moment_bytes(s)
    raise ValueError("no direction stage found in state")
