"""Frequent Directions sketching (paper Alg. 1 + exponentially-weighted Obs. 6).

The sketch of a PSD stream ``G_t = sum_s beta2^{t-s} A_s A_s^T`` is maintained
in *eigenpair form* ``(U, s, rho)`` with ``U: (d, ell)`` orthonormal columns,
``s: (ell,)`` descending eigenvalues (deflation keeps ``s[-1] == 0``), and
``rho`` the accumulated escaped mass used for the dynamic diagonal
compensation ``rho * I`` (the paper's key construction, Alg. 2/3 line 6).

TPU adaptation (DESIGN.md §3): instead of eigendecomposing the d x d matrix
(Alg. 1 line 3) or SVD-ing the d x (ell+r) stack (paper §6), we
eigendecompose the (ell+r) x (ell+r) Gram matrix of ``M = [sqrt(beta2)*B, A]``
— one tall-skinny MXU matmul plus a small eigh. Identical result, never
materializes d x d, and avoids large-matrix SVD which TPUs lack.

Kernel injection: every function takes an optional
``kernels: repro.kernels.registry.KernelSet``.  The single-block entry
points use ``kernels.gram`` / ``kernels.lowrank_apply``; the ``*_batched``
variants — the pooled-engine hot path, operating on a whole packed
``(N, ...)`` pool stack at once — use ``kernels.batched_gram`` /
``kernels.batched_lowrank_apply`` (grid-over-N Pallas kernels on TPU).  With
``kernels=None`` everything falls back to plain jnp, and the batched jnp
expressions mirror ``jax.vmap`` of the single-block ones primitive-for-
primitive so the synchronized schedule stays bitwise-reproducible.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FDState(NamedTuple):
    eigvecs: jnp.ndarray  # (d, ell) approximate top eigenvectors U
    eigvals: jnp.ndarray  # (ell,) deflated eigenvalues, descending, last == 0
    rho: jnp.ndarray      # scalar: accumulated escaped mass rho_{1:t}


def fd_init(d: int, ell: int, dtype=jnp.float32) -> FDState:
    ell = min(ell, d)
    return FDState(
        eigvecs=jnp.zeros((d, ell), dtype),
        eigvals=jnp.zeros((ell,), dtype),
        rho=jnp.zeros((), dtype),
    )


def fd_update(state: FDState, new_factor: jnp.ndarray, beta2: float = 1.0,
              kernels=None) -> FDState:
    """One FD-update step on the PSD increment ``new_factor @ new_factor.T``.

    Args:
      state: current sketch.
      new_factor: (d, r) factor A of the new PSD term M_t = A A^T. For
        S-AdaGrad this is the gradient column g_t[:, None]; for S-Shampoo's
        left factor it is the gradient matrix G_t itself (L += G G^T), and
        G_t^T for the right factor.
      beta2: EMA decay (1.0 recovers the unweighted paper Alg. 1).
      kernels: optional ``KernelSet``; ``kernels.gram`` supplies the
        C = M^T M contraction (Pallas kernel injection point).

    Returns:
      Updated state; ``state.rho`` accumulates escaped mass with the same
      beta2 decay (DESIGN.md §6 — plain sum when beta2 == 1).
    """
    U, s, rho = state
    d, ell = U.shape
    if new_factor.ndim == 1:
        new_factor = new_factor[:, None]
    compute_dtype = jnp.promote_types(U.dtype, jnp.float32)

    # M = [sqrt(beta2) * B, A] where B = U diag(sqrt(s)).  The eigenvalue
    # ladder is non-negative by construction, so the clamp is a bitwise
    # no-op in fp32 — it only guards sqrt(negative) -> NaN when quantized
    # state storage (core/quantize.py) or a lossy checkpoint restore
    # perturbs s below zero.
    s_clamped = jnp.maximum(beta2 * s.astype(compute_dtype), 0.0)
    B = U.astype(compute_dtype) * jnp.sqrt(s_clamped)[None, :]
    M = jnp.concatenate([B, new_factor.astype(compute_dtype)], axis=1)  # (d, ell+r)

    if kernels is None:
        C = M.T @ M
    else:
        C = kernels.gram(M)
    C = 0.5 * (C + C.T)  # symmetrize for eigh stability

    lam, V = jnp.linalg.eigh(C)          # ascending
    lam = jnp.maximum(lam[::-1], 0.0)    # descending, clip tiny negatives
    V = V[:, ::-1]

    lam_top = lam[:ell]
    # Escaped eigenvalue: lambda_ell of the un-deflated update. When the
    # stacked matrix has rank <= ell the escaped mass is genuinely 0 (it is
    # lam[ell-1] only after deflation below keeps the invariant s[-1] == 0).
    rho_t = lam_top[ell - 1]

    inv_sqrt = jnp.where(lam_top > 1e-30, jax.lax.rsqrt(jnp.maximum(lam_top, 1e-30)), 0.0)
    U_new = (M @ V[:, :ell]) * inv_sqrt[None, :]
    s_new = lam_top - rho_t  # deflate: last entry becomes exactly 0

    return FDState(
        eigvecs=U_new.astype(U.dtype),
        eigvals=s_new.astype(s.dtype),
        rho=(beta2 * rho + rho_t).astype(state.rho.dtype),
    )


def fd_update_batched(state: FDState, new_factor: jnp.ndarray,
                      beta2: float = 1.0, kernels=None,
                      active_k: jnp.ndarray | None = None) -> FDState:
    """``fd_update`` over a whole packed pool stack in one batched call.

    ``state`` leaves carry a leading pool dim N (eigvecs (N, d, ell), eigvals
    (N, ell), rho (N,)); ``new_factor`` is (N, d, r).  With ``kernels`` the
    Gram goes through ``kernels.batched_gram`` (grid-over-N Pallas on TPU);
    without, the jnp expressions mirror ``jax.vmap(fd_update)`` exactly.

    Masked ranks: ``active_k`` (N,) int restricts block ``b`` to its leading
    ``active_k[b]`` ladder columns — the stack keeps full ``ell`` capacity
    (shapes never change) but the FD recurrence runs at the smaller rank:
    only the active columns enter the Gram, deflation subtracts
    ``lam[active_k[b]-1]`` instead of ``lam[ell-1]``, and columns at or past
    ``active_k[b]`` come back exactly zero.  ``active_k=None`` is the
    unmasked path, bitwise-identical to before the rank-budget allocator.

    Quantized compute path: when ``state.eigvecs`` is a ``QuantizedPool``
    (int8 values + per-block scale; the engine's fused int8 mode keeps the
    storage container through the batched methods instead of dequantizing
    at the boundary), the Gram and the eigenvector write-back run through
    the fused quantized entries — the (N, d, ell) f32 eigenvector stack is
    never materialized.  The per-block dequant scale and the sqrt-
    eigenvalue ladder weights are both per-*column* of the small factor,
    so they fold into one (N, ell) weight vector exactly:

        B = dequant(Vq) sqrt(beta2 s) = Vq diag(colw),
        colw = scale * sqrt(beta2 s).

    The refreshed eigenvectors come back already re-quantized (the fused
    epilogue's round-to-nearest matches ``quantize.quantize_stack`` with
    no key), so the state returned here is a new ``QuantizedPool``.
    """
    U, s, rho = state
    if _is_quantized(U):
        return _fd_update_batched_quantized(U, s, rho, new_factor, beta2,
                                            kernels, active_k)
    _, d, ell = U.shape
    if new_factor.ndim == 2:
        new_factor = new_factor[..., None]
    compute_dtype = jnp.promote_types(U.dtype, jnp.float32)

    # non-negative clamp mirrors fd_update: free in fp32, NaN guard under
    # quantized storage
    s_clamped = jnp.maximum(beta2 * s.astype(compute_dtype), 0.0)
    kmask = _rank_mask(active_k, ell)
    if kmask is not None:
        s_clamped = jnp.where(kmask, s_clamped, 0.0)
    B = U.astype(compute_dtype) * jnp.sqrt(s_clamped)[:, None, :]
    M = jnp.concatenate([B, new_factor.astype(compute_dtype)], axis=2)

    if kernels is None:
        C = jnp.matmul(jnp.swapaxes(M, -1, -2), M)
    else:
        C = kernels.batched_gram(M)
    C = 0.5 * (C + jnp.swapaxes(C, -1, -2))

    lam, V = jnp.linalg.eigh(C)             # ascending, batched
    lam = jnp.maximum(lam[..., ::-1], 0.0)  # descending, clip tiny negatives
    V = V[..., ::-1]

    lam_top = lam[..., :ell]
    rho_t = _escaped_eigval(lam_top, active_k, ell)   # (N,)

    inv_sqrt = jnp.where(lam_top > 1e-30,
                         jax.lax.rsqrt(jnp.maximum(lam_top, 1e-30)), 0.0)
    U_new = jnp.matmul(M, V[..., :ell]) * inv_sqrt[:, None, :]
    s_new = lam_top - rho_t[..., None]
    if kmask is not None:
        U_new = jnp.where(kmask[:, None, :], U_new, 0.0)
        s_new = jnp.where(kmask, s_new, 0.0)

    return FDState(
        eigvecs=U_new.astype(U.dtype),
        eigvals=s_new.astype(s.dtype),
        rho=(beta2 * rho + rho_t).astype(state.rho.dtype),
    )


def _rank_mask(active_k, ell: int):
    """(N, ell) bool mask of active ladder columns, or None when unmasked."""
    if active_k is None:
        return None
    kk = jnp.clip(active_k, 1, ell)
    return jnp.arange(ell)[None, :] < kk[:, None]


def _escaped_eigval(lam_top: jnp.ndarray, active_k, ell: int) -> jnp.ndarray:
    """Per-block deflation eigenvalue: ``lam[k-1]`` at the active rank."""
    if active_k is None:
        return lam_top[..., ell - 1]
    kk = jnp.clip(active_k, 1, ell)
    return jnp.take_along_axis(lam_top, kk[:, None] - 1, axis=-1)[..., 0]


def _is_quantized(x) -> bool:
    """True when ``x`` is a core.quantize.QuantizedPool (lazy import — fd is
    imported by modules below quantize in the package graph)."""
    from repro.core import quantize
    return isinstance(x, quantize.QuantizedPool)


def _fd_update_batched_quantized(U, s, rho, new_factor, beta2, kernels,
                                 active_k=None) -> FDState:
    """``fd_update_batched`` with the eigenvector stack in int8 pool storage
    end to end; see the caller's docstring for the scale-folding algebra."""
    from repro.core import quantize

    vq, scale = U.values, U.scale            # (N, d, ell) int8, (N, 1, 1)
    N, d, ell = vq.shape
    if new_factor.ndim == 2:
        new_factor = new_factor[..., None]
    A = new_factor.astype(jnp.float32)       # (N, d, r)

    s_clamped = jnp.maximum(beta2 * s.astype(jnp.float32), 0.0)
    kmask = _rank_mask(active_k, ell)
    if kmask is not None:
        # masking the column weights zeroes inactive columns of B exactly,
        # regardless of what the int8 values hold there
        s_clamped = jnp.where(kmask, s_clamped, 0.0)
    colw = scale.reshape(N, 1) * jnp.sqrt(s_clamped)   # (N, ell)

    if kernels is None:
        m = jnp.concatenate(
            [vq.astype(jnp.float32) * colw[:, None, :], A], axis=2)
        C = jnp.matmul(jnp.swapaxes(m, -1, -2), m)
    else:
        C = kernels.batched_gram_mixed(vq, colw, A)
    C = 0.5 * (C + jnp.swapaxes(C, -1, -2))

    lam, V = jnp.linalg.eigh(C)             # ascending, batched
    lam = jnp.maximum(lam[..., ::-1], 0.0)  # descending, clip tiny negatives
    V = V[..., ::-1]

    lam_top = lam[..., :ell]
    rho_t = _escaped_eigval(lam_top, active_k, ell)   # (N,)

    inv_sqrt = jnp.where(lam_top > 1e-30,
                         jax.lax.rsqrt(jnp.maximum(lam_top, 1e-30)), 0.0)
    # U_new = M @ W with M = [Vq diag(colw), A]: split W by row block and
    # fold the column weights into the top half so the projection consumes
    # the raw int8 values directly
    W = V[..., :ell] * inv_sqrt[:, None, :]           # (N, ell+r, ell)
    if kmask is not None:
        # masking W's output columns keeps inactive eigenvector columns at
        # zero through the in-kernel quantization as well
        W = jnp.where(kmask[:, None, :], W, 0.0)
    w_top = colw[..., None] * W[..., :ell, :]         # (N, ell, ell)
    w_bot = W[..., ell:, :]                           # (N, r, ell)

    if kernels is None:
        un = jnp.matmul(vq.astype(jnp.float32), w_top) + jnp.matmul(A, w_bot)
        qp = quantize.quantize_stack(un)
    else:
        values, scale_new = kernels.batched_project_quantize(
            vq, w_top, A, w_bot)
        qp = quantize.QuantizedPool(values=values, scale=scale_new)

    s_new = lam_top - rho_t[..., None]
    if kmask is not None:
        s_new = jnp.where(kmask, s_new, 0.0)
    return FDState(
        eigvecs=qp,
        eigvals=s_new.astype(s.dtype),
        rho=(beta2 * rho + rho_t).astype(rho.dtype),
    )


def fd_resize_batched(state: FDState, new_k: jnp.ndarray) -> FDState:
    """Move each block of a pooled sketch stack to a new active rank.

    Capacity (array shapes) never changes — this is the rank-*migration*
    primitive for the budget allocator.  Shrinking block ``b`` to
    ``new_k[b]`` folds the dropped eigenvalue mass into ``rho`` exactly
    (Robust-FD redistribution: ``rho += sum_{i >= new_k} s_i``) and zeroes
    the dropped ladder columns in place, so the per-block FD guarantee
    ``||G - sketch|| <= rho`` is preserved.  Growing is free: columns at or
    past the old active rank are already zero and simply become eligible
    for the next masked ``fd_update_batched``.

    Works on fp32/bf16 stacks and on ``QuantizedPool`` eigenvector storage
    (int8 values are masked in place; the per-block scale is unchanged).
    """
    U, s, rho = state
    quantized = _is_quantized(U)
    ell = (U.values if quantized else U).shape[-1]
    kmask = _rank_mask(new_k, ell)                      # (N, ell)
    s_f = s.astype(jnp.float32)
    dropped = jnp.sum(jnp.where(kmask, 0.0, s_f), axis=-1)   # (N,)
    s_new = jnp.where(kmask, s_f, 0.0).astype(s.dtype)
    rho_new = (rho.astype(jnp.float32) + dropped).astype(rho.dtype)
    if quantized:
        from repro.core import quantize
        U_new = quantize.QuantizedPool(
            values=jnp.where(kmask[:, None, :], U.values,
                             jnp.zeros((), jnp.int8)),
            scale=U.scale)
    else:
        U_new = jnp.where(kmask[:, None, :], U, 0.0).astype(U.dtype)
    return FDState(eigvecs=U_new, eigvals=s_new, rho=rho_new)


def fd_weighted_factor(state: FDState, *, drop_deflated: bool = False
                       ) -> jnp.ndarray:
    """Factor ``B = U diag(sqrt(s))`` with ``B B^T == U diag(s) U^T``.

    Works on a single state (``U (d, ell)`` -> ``(d, ell)``) or a pooled
    stack (``U (N, d, ell)`` -> ``(N, d, ell)``).  With ``drop_deflated``
    the last column is omitted: the deflation invariant ``s[-1] == 0`` makes
    it identically zero, so the merge wire format (distributed/
    sketch_merge.py) sends ``ell - 1`` columns per side without loss.
    """
    U, s, _ = state
    compute_dtype = jnp.promote_types(U.dtype, jnp.float32)
    s_clamped = jnp.maximum(s.astype(compute_dtype), 0.0)
    B = U.astype(compute_dtype) * jnp.sqrt(s_clamped)[..., None, :]
    if drop_deflated and B.shape[-1] > 1:
        B = B[..., :-1]
    return B


def fd_merge_factors_batched(Ba: jnp.ndarray, rho_a: jnp.ndarray,
                             Bb: jnp.ndarray, rho_b: jnp.ndarray, *,
                             ell: int, kernels=None) -> FDState:
    """Merge two weighted-factor stacks into one rank-``ell`` sketch stack.

    This is the mergeable-sketch primitive (Robust FD, Luo et al.): the
    union covariance ``Ba Ba^T + Bb Bb^T`` is re-sketched by stacking the
    factors, eigendecomposing the small Gram (same batched-gram kernel path
    as ``fd_update_batched``), and deflating by the escaped eigenvalue
    ``rho_t``; the carried masses add, so the merged ``rho*I`` compensation
    stays an upper bound on the total escaped mass.

    Args:
      Ba, Bb: (N, d, ra) / (N, d, rb) factor stacks (``fd_weighted_factor``).
      rho_a, rho_b: (N,) escaped masses carried by each side.
      ell: target sketch rank of the merged state.
      kernels: optional ``KernelSet`` for the batched Gram.
    """
    M = jnp.concatenate([Ba.astype(jnp.float32), Bb.astype(jnp.float32)],
                        axis=-1)                       # (N, d, ra+rb)
    if M.shape[-1] < ell:                              # skinny sides: pad so
        pad = ell - M.shape[-1]                        # U keeps (N, d, ell)
        M = jnp.pad(M, ((0, 0), (0, 0), (0, pad)))

    if kernels is None:
        C = jnp.matmul(jnp.swapaxes(M, -1, -2), M)
    else:
        C = kernels.batched_gram(M)
    C = 0.5 * (C + jnp.swapaxes(C, -1, -2))

    lam, V = jnp.linalg.eigh(C)             # ascending, batched
    lam = jnp.maximum(lam[..., ::-1], 0.0)  # descending, clip tiny negatives
    V = V[..., ::-1]

    lam_top = lam[..., :ell]
    rho_t = lam_top[..., ell - 1]           # (N,) escaped eigenvalue

    inv_sqrt = jnp.where(lam_top > 1e-30,
                         jax.lax.rsqrt(jnp.maximum(lam_top, 1e-30)), 0.0)
    U_new = jnp.matmul(M, V[..., :ell]) * inv_sqrt[:, None, :]
    s_new = lam_top - rho_t[..., None]      # deflate: last entry exactly 0

    return FDState(eigvecs=U_new, eigvals=s_new,
                   rho=rho_a.astype(jnp.float32) + rho_b.astype(jnp.float32)
                   + rho_t)


def fd_merge_batched(a: FDState, b: FDState, kernels=None) -> FDState:
    """Merge two pooled sketch stacks of the same shape (leading dim N).

    ``cov(merged) ~= cov(a) + cov(b)`` within the FD bound: the operator-
    norm error of the merged sketch against the exact sum is at most
    ``merged.rho`` (escaped masses are additive through the merge)."""
    _, _, ell = a.eigvecs.shape
    out = fd_merge_factors_batched(
        fd_weighted_factor(a), a.rho, fd_weighted_factor(b), b.rho,
        ell=ell, kernels=kernels)
    return FDState(eigvecs=out.eigvecs.astype(a.eigvecs.dtype),
                   eigvals=out.eigvals.astype(a.eigvals.dtype),
                   rho=out.rho.astype(a.rho.dtype))


def fd_merge(a: FDState, b: FDState, kernels=None) -> FDState:
    """Merge two single-block sketches (``U (d, ell)``); see
    ``fd_merge_batched``.  Mergeability is what makes the sketch a
    distributed-friendly statistic: shards sketch their local streams and
    the combined sketch matches a single-stream sketch of the union within
    the FD error bound (tests/test_fd.py)."""
    stack = jax.tree.map(lambda x: x[None], a), jax.tree.map(
        lambda x: x[None], b)
    out = fd_merge_batched(stack[0], stack[1], kernels=kernels)
    return FDState(*(x[0] for x in out))


def fd_pressure(state: FDState) -> jnp.ndarray:
    """Escaped-mass ratio ``rho / (trace + rho)`` in [0, 1].

    The sketch's own estimate of how much of the stream it is failing to
    capture: near 0 the leading-``ell`` subspace holds the stream, near 1
    the mass escapes past the sketch rank.  This is the drift-pressure
    signal shared by the rank-budget allocator (``rho_greedy`` pouring) and
    the serve-time gradient monitor (serve/monitor.py).  Batch-polymorphic:
    pooled states (eigvals (N, ell), rho (N,)) return an (N,) vector.
    """
    trace = jnp.sum(state.eigvals.astype(jnp.float32), axis=-1)
    rho = state.rho.astype(jnp.float32)
    return rho / jnp.maximum(trace + rho, 1e-30)


def fd_leading_eigval(state: FDState, *, compensated: bool = True
                      ) -> jnp.ndarray:
    """Top eigenvalue of the sketched covariance.  With ``compensated``
    (default) this is the top eigenvalue of the rho-compensated estimate
    ``U diag(s) U^T + rho I`` — i.e. ``s[0] + rho`` — matching what the
    preconditioner actually applies; without, the raw deflated ladder top.
    Batch-polymorphic like ``fd_pressure``."""
    top = state.eigvals[..., 0].astype(jnp.float32)
    if compensated:
        top = top + state.rho.astype(jnp.float32)
    return top


def fd_subspace_angle(a, b, k: int = None) -> jnp.ndarray:
    """Largest principal angle (radians) between the leading-``k`` sketch
    subspaces of ``a`` and ``b`` (FDState or raw (d, ell) eigvec arrays).

    ``arccos(sigma_min(Ua^T Ub))``: 0 when the subspaces coincide, pi/2 when
    some direction of one is orthogonal to all of the other.  ``k`` defaults
    to ``ell - 1`` (the deflation invariant keeps the last ladder column
    zero, which would read as a spurious right angle).  A column that is
    still zero (un-warmed sketch, low-rank window) saturates the angle at
    pi/2 — callers should compare sketches that have both seen data.
    """
    Ua = a.eigvecs if isinstance(a, FDState) else a
    Ub = b.eigvecs if isinstance(b, FDState) else b
    if k is None:
        k = max(Ua.shape[-1] - 1, 1)
    k = min(k, Ua.shape[-1], Ub.shape[-1])
    C = Ua[..., :k].astype(jnp.float32).T @ Ub[..., :k].astype(jnp.float32)
    sv = jnp.linalg.svd(C, compute_uv=False)
    return jnp.arccos(jnp.clip(jnp.min(sv, axis=-1), 0.0, 1.0))


def fd_covariance(state: FDState, include_rho: bool = False) -> jnp.ndarray:
    """Materialize the sketched covariance (testing/analysis only)."""
    U, s, rho = state
    cov = (U * s[None, :]) @ U.T
    if include_rho:
        cov = cov + rho * jnp.eye(U.shape[0], dtype=cov.dtype)
    return cov


def fd_inverse_root_coeffs(state: FDState, *, exponent: float, eps: float
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Coefficients for applying (U diag(s) U^T + (rho+eps) I)^{exponent}.

    Returns (base, coeffs) such that
      apply(G) = base * G + U @ diag(coeffs) @ (U^T @ G)
    Uses the eigenpair representation: eigenvalues of the compensated
    preconditioner are (s_i + rho + eps) on span(U) and (rho + eps) on the
    orthogonal complement. Elementwise — no iterative root solve needed.
    Batch-polymorphic: with a pooled state (s (N, ell), rho (N,)) it returns
    base (N,) and coeffs (N, ell).
    """
    _, s, rho = state
    damp = rho + eps
    # Moore-Penrose semantics (Alg. 2 uses the pseudoinverse): with no
    # diagonal mass, directions outside span(U) map to 0, not eps^exponent.
    tol = 1e-10
    base = jnp.where(damp > tol, jnp.power(jnp.maximum(damp, tol), exponent),
                     0.0)
    lam = s + damp[..., None]
    coeffs = jnp.where(lam > tol, jnp.power(jnp.maximum(lam, tol), exponent),
                       0.0) - base[..., None]
    return base, coeffs


def fd_apply_inverse_root(state: FDState, G: jnp.ndarray, *, exponent: float,
                          eps: float, kernels=None) -> jnp.ndarray:
    """Compute (sketch + (rho+eps) I)^{exponent} @ G without forming d x d.

    kernels: optional ``KernelSet``; ``kernels.lowrank_apply`` supplies the
    fused low-rank + diagonal apply.
    """
    base, coeffs = fd_inverse_root_coeffs(state, exponent=exponent, eps=eps)
    U = state.eigvecs
    if kernels is not None:
        return kernels.lowrank_apply(U, coeffs, base, G)
    proj = U.T @ G
    return base * G + U @ (coeffs[:, None] * proj)


def fd_apply_inverse_root_batched(state: FDState, G: jnp.ndarray, *,
                                  exponent: float, eps: float,
                                  kernels=None) -> jnp.ndarray:
    """``fd_apply_inverse_root`` over a packed pool stack (state leaves and
    G carry a leading pool dim N).  With ``kernels`` the fused apply goes
    through ``kernels.batched_lowrank_apply``; without, the jnp expressions
    mirror ``jax.vmap(fd_apply_inverse_root)`` exactly.

    A ``QuantizedPool`` eigenvector stack is consumed directly: the
    per-block scale commutes out of ``U diag(c) U^T`` as ``scale^2``, so
    the kernel path folds it into the coefficients and runs on the raw
    int8 values (``kernels.batched_lowrank_apply_quantized``)."""
    base, coeffs = fd_inverse_root_coeffs(state, exponent=exponent, eps=eps)
    U = state.eigvecs
    if _is_quantized(U):
        if kernels is not None:
            return kernels.batched_lowrank_apply_quantized(
                U.values, U.scale, coeffs, base, G)
        U = U.values.astype(jnp.float32) * U.scale
    elif kernels is not None:
        return kernels.batched_lowrank_apply(U, coeffs, base, G)
    proj = jnp.matmul(jnp.swapaxes(U, -1, -2), G)
    return base[..., None, None] * G + jnp.matmul(U, coeffs[..., None] * proj)
