"""Packed block-pool layout: shape-grouped cross-parameter block stacks.

The engine (core/api.py) used to dispatch ``update_stats/refresh/
precondition`` once **per parameter leaf**, compiling a separate vmap'd
kernel set for every leaf even though a transformer has hundreds of leaves
sharing a handful of block shapes.  This module groups *every* matrix block
in the model by its padded block shape ``(bs_m, bs_n)`` into one packed
``(N, bs_m, bs_n)`` stack per unique shape, so the engine runs each
Preconditioner method once per *shape group* — a 400-leaf model compiles
~3-5 kernel sets instead of ~400, and the pooled leading dim ``N`` spans the
whole model, which is what lets ``trainer.train_state_shardings`` shard FD
refresh over the full ``('model', 'data')`` mesh (the ``opt_blocks`` logical
axis, sharding/rules.py).

Everything here is static Python over shapes: ``build_index`` is computed
from the parameter treedef once (LRU-cached), ``pack``/``unpack`` are pure
reshapes/concats under jit.  Block order within a group is canonical —
parameter leaves in flat-tree order, then row-major tile order within each
leaf (blocking.to_blocks) — so checkpoints and shardings are reproducible
from shapes alone.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import blocking


def group_key(bs_m: int, bs_n: int) -> str:
    """Canonical pool-dict key for a block shape (stable checkpoint paths)."""
    return f"{bs_m}x{bs_n}"


@dataclasses.dataclass(frozen=True)
class PoolGroup:
    """One packed stack: all model blocks of one ``(bs_m, bs_n)`` shape."""
    key: str
    bs_m: int
    bs_n: int
    num_blocks: int          # N — total blocks across all member leaves
    leaf_ids: tuple          # flat param indices contributing, in pack order

    @property
    def info(self) -> blocking.BlockInfo:
        """Representative BlockInfo for ``Preconditioner.init_block`` (only
        the block dims are meaningful at the group level)."""
        return blocking.BlockInfo(kind="matrix", shape=(self.bs_m, self.bs_n),
                                  stack=1, m=self.bs_m, n=self.bs_n,
                                  bs_m=self.bs_m, bs_n=self.bs_n, mb=1, nb=1)


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """Where one parameter leaf's blocks live."""
    info: blocking.BlockInfo
    group: Optional[int] = None   # index into PoolIndex.groups ('matrix')
    offset: int = 0               # block offset within the group stack


@dataclasses.dataclass(frozen=True)
class PoolIndex:
    """Static scatter/gather map between the param tree and the pools."""
    groups: tuple          # tuple[PoolGroup]
    leaves: tuple          # tuple[LeafPlan], one per flat param leaf

    @property
    def total_blocks(self) -> int:
        return sum(g.num_blocks for g in self.groups)


@functools.lru_cache(maxsize=None)
def build_index(shapes: tuple, block_size: int = 1024, *,
                vectors_as_columns: bool = False) -> PoolIndex:
    """Group every matrix leaf's blocks by block shape.

    ``shapes`` is the tuple of flat parameter shapes (hashable => cached per
    model).  Leaves that analyze to 'diag' get a plan with ``group=None`` and
    stay on the engine's per-leaf diagonal path.
    """
    members: dict = {}               # key -> list[(leaf_id, num_blocks)]
    infos = [blocking.analyze_leaf(tuple(s), block_size,
                                   vectors_as_columns=vectors_as_columns)
             for s in shapes]
    for i, info in enumerate(infos):
        if info.kind != "matrix":
            continue
        members.setdefault(group_key(info.bs_m, info.bs_n), []).append(
            (i, info.num_blocks))

    groups, plans = [], [None] * len(infos)
    for gi, key in enumerate(sorted(members)):  # sorted: match dict-pytree order
        offset = 0
        leaf_ids = []
        for i, nb in members[key]:
            plans[i] = LeafPlan(info=infos[i], group=gi, offset=offset)
            offset += nb
            leaf_ids.append(i)
        bs_m, bs_n = infos[leaf_ids[0]].block_shape
        groups.append(PoolGroup(key=key, bs_m=bs_m, bs_n=bs_n,
                                num_blocks=offset, leaf_ids=tuple(leaf_ids)))
    for i, info in enumerate(infos):
        if plans[i] is None:
            plans[i] = LeafPlan(info=info)
    return PoolIndex(groups=tuple(groups), leaves=tuple(plans))


def pack(index: PoolIndex, flat_leaves) -> dict:
    """Flat (f32) gradient leaves -> {group key: (N, bs_m, bs_n) stack}.

    Blocks are concatenated in canonical order (leaf order, then tile order),
    matching ``LeafPlan.offset``.
    """
    per_group: dict = {g.key: [] for g in index.groups}
    for leaf, plan in zip(flat_leaves, index.leaves):
        if plan.group is None:
            continue
        per_group[index.groups[plan.group].key].append(
            blocking.to_blocks(leaf, plan.info))
    return {key: (blocks[0] if len(blocks) == 1
                  else jnp.concatenate(blocks, axis=0))
            for key, blocks in per_group.items()}


def unpack_leaf(index: PoolIndex, pools: dict, leaf_id: int) -> jnp.ndarray:
    """Slice one leaf's blocks out of its pool and restore the leaf shape."""
    plan = index.leaves[leaf_id]
    assert plan.group is not None, f"leaf {leaf_id} is not pooled"
    stack = pools[index.groups[plan.group].key]
    blocks = stack[plan.offset:plan.offset + plan.info.num_blocks]
    return blocking.from_blocks(blocks, plan.info)


def unpack(index: PoolIndex, pools: dict) -> list:
    """{group key: (N, bs_m, bs_n)} -> flat list of leaf arrays (``None`` at
    non-pooled positions)."""
    return [unpack_leaf(index, pools, i) if plan.group is not None else None
            for i, plan in enumerate(index.leaves)]


def block_ids(group: PoolGroup) -> jnp.ndarray:
    """Global block positions within a group stack — the staggered-refresh
    phase source (core/api.py)."""
    return jnp.arange(group.num_blocks, dtype=jnp.int32)


def uniform_ranks(n: int, total: int, min_k: int, max_k: int) -> jnp.ndarray:
    """Deterministic initial allocation: spread ``total`` over ``n`` blocks
    as evenly as possible (earlier blocks get the remainder), clipped to
    ``[min_k, max_k]``.  Feasibility (``n*min_k <= total <= n*max_k``) is
    validated by the caller at config time."""
    base = total // n
    k = base + (jnp.arange(n) < (total - base * n))
    return jnp.clip(k, min_k, max_k).astype(jnp.int32)


def allocate_ranks(pressure: jnp.ndarray, *, total: int, min_k: int,
                   max_k) -> jnp.ndarray:
    """Greedy waterfill of a fixed total rank budget by descending pressure.

    Every block is floored at ``min_k``; the remaining budget
    ``R = total - sum(min_k)`` is poured into blocks in descending
    ``pressure`` order, each taking up to its headroom ``max_k - min_k``
    before the next one gets any.  Exact and jit-friendly: one stable
    argsort (ties break by block index, so the allocation is deterministic)
    plus a cumulative sum — no data-dependent control flow, so it runs
    under ``lax.cond`` at refresh boundaries.

    Args:
      pressure: (N,) per-block starvation signal (e.g. the escaped-mass
        ratio ``rho / (trace + rho)`` — high means the sketch is dropping
        mass and wants more columns).
      total: fixed budget ``K_total`` with ``sum(result) == total`` whenever
        ``N*min_k <= total <= sum(max_k)`` (guaranteed at config time).
      min_k: scalar per-block floor.
      max_k: scalar or (N,) per-block ceiling (capacity ``min(ell, d)``).

    Returns:
      (N,) int32 ranks with ``min_k <= k_b <= max_k``.
    """
    n = pressure.shape[0]
    max_k = jnp.broadcast_to(jnp.asarray(max_k, jnp.int32), (n,))
    room = jnp.maximum(max_k - min_k, 0)                     # (N,)
    budget = jnp.clip(total - n * min_k, 0, jnp.sum(room))
    order = jnp.argsort(-pressure, stable=True)              # descending
    room_sorted = room[order]
    ahead = jnp.cumsum(room_sorted) - room_sorted            # taken by better-ranked
    give_sorted = jnp.clip(budget - ahead, 0, room_sorted)
    give = jnp.zeros((n,), jnp.int32).at[order].set(
        give_sorted.astype(jnp.int32))
    return (min_k + give).astype(jnp.int32)


def commit_select(valid, pending, live):
    """Storage-level commit of an in-flight refresh cohort
    (``refresh_mode="async"``, core/api.py): where ``valid``, take the
    pending stack, else keep the live one.

    ``pending``/``live`` are two congruent (untagged) stat trees in storage
    layout; ``valid`` is a scalar bool (one in-flight cohort per group) or a
    per-block ``(N,)`` mask — scalars broadcast over every leaf, a mask is
    rank-expanded to each leaf's trailing dims.  This is an elementwise
    select: no gather/scatter, no eigh, nothing on the critical path but a
    ``jnp.where`` per leaf.
    """
    def sel(p, l):
        v = valid
        if getattr(v, "ndim", 0) == 1 and p.ndim >= 1:
            v = v.reshape(v.shape + (1,) * (p.ndim - 1))
        return jnp.where(v, p, l)
    return jax.tree.map(sel, pending, live)
