"""Pool-level quantized storage for second-moment optimizer state.

Sketchy's pitch is sub-linear second-moment memory (dk instead of d^2);
this module compresses exactly that state further by storing the packed
``(N, bs_m, bs_n)`` pool stacks (core/pool.py) in low precision *between*
steps.  Compute stays f32: the engine dequantizes at the
``update_stats_batched / refresh_batched / precondition_batched`` boundary
and re-quantizes the result, so the kernel registry and every
Preconditioner implementation are untouched.

Three storage modes (``EngineConfig.second_moment_dtype``):

  * ``"fp32"`` — identity.  Bitwise-identical to the unquantized engine
    (pinned in tests/test_quantize.py against tests/reference_impls.py).
  * ``"bf16"`` — every second-moment leaf cast to bfloat16 (2x).
  * ``"int8"`` — per-block symmetric int8: each block's matrix factors
    (FD eigenvector stacks, Shampoo L/R Grams — the O(d*ell) / O(d^2)
    terms of the paper's Fig. 1 budget) are stored as int8 values plus one
    fp32 absmax scale per block (~4x).  Per-block *vectors and scalars*
    (the FD eigenvalue ladder, escaped mass rho) stay fp32: they are
    O(ell) of the budget, and the deflation invariant ``s[-1] == 0`` plus
    the ``rho * I`` compensation do not survive rounding noise.

The int8 container is ``QuantizedPool(values, scale)`` — a plain NamedTuple
pytree whose fields are individually ``Tagged`` (core/api.py) with the
original leaf's ``StateMeta``.  Because each Tagged node still wraps exactly
one array, every metadata-driven consumer works unchanged:
``api.second_moment_bytes`` reports the *compressed* footprint (int8 values
+ fp32 scales), ``trainer.train_state_shardings`` shards the scale stack's
leading ``N`` dim alongside its values (sharding/rules.blocks_sharding),
and ``train/checkpoint.py`` manifests both leaves (with a cross-dtype
migration shim for restoring fp32 checkpoints into int8 runs and back).

The scale/round core (absmax -> int8 range, stochastic rounding for
unbiased repeated quantize-accumulate cycles) is shared with the int8
gradient all-reduce in ``train/compression.py`` — one rounding rule for
state at rest and gradients in flight.

Rank-budgeted stacks (core/sketchy.RankBudget) quantize transparently:
blocks running below ladder capacity keep their masked eigenvector columns
exactly zero (absmax scaling maps 0 -> 0, so masking survives the int8
round-trip), and the per-block active-rank vector ``k`` is an int32 count
leaf — never matched by ``_int8_eligible`` (role ``"count"``, ndim 1) and
excluded from ``second_moment_bytes``, so the budgeted footprint stays
byte-identical to a static run at the same capacity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import api

SECOND_MOMENT_DTYPES = ("fp32", "bf16", "int8")

_INT8_MAX = 127.0


class QuantizedPool(NamedTuple):
    """One int8-quantized pool stack: integer values + per-block fp32 scale.

    In engine state both fields are ``Tagged`` with the source leaf's
    ``StateMeta`` (role="second_moment", blocked=True); ``scale`` keeps the
    leading blocks dim (``(N, 1, ..., 1)``) so it shards alongside
    ``values`` and broadcasts in ``dequantize_stack``.
    """
    values: Any
    scale: Any


def _is_node(x) -> bool:
    return isinstance(x, (QuantizedPool, api.Tagged))


# ---------------------------------------------------------------------------
# Shared scale/round core (also used by train/compression.py's int8 psum)


def int8_scale(absmax: jnp.ndarray) -> jnp.ndarray:
    """absmax -> fp32 scale mapping ``|x| <= absmax`` onto the int8 range."""
    return jnp.where(absmax > 0, absmax / _INT8_MAX, 1.0)


def round_int8(scaled: jnp.ndarray, key=None) -> jnp.ndarray:
    """Round pre-scaled values to int8.

    With a PRNG ``key`` the rounding is stochastic (unbiased under repeated
    quantize-accumulate cycles — EMA statistics, compressed all-reduce);
    without it, round-to-nearest (deterministic restores).  Either way an
    already-integer input is a fixed point, so re-quantizing an unchanged
    dequantized stack does not random-walk the state.
    """
    if key is not None:
        noise = jax.random.uniform(key, scaled.shape, jnp.float32) - 0.5
        scaled = scaled + noise
    return jnp.clip(jnp.round(scaled), -_INT8_MAX, _INT8_MAX).astype(jnp.int8)


def quantize_stack(x: jnp.ndarray, *, key=None) -> QuantizedPool:
    """``(N, ...)`` float stack -> int8 values + one fp32 scale per block."""
    x32 = x.astype(jnp.float32)
    axes = tuple(range(1, x32.ndim))
    absmax = jnp.max(jnp.abs(x32), axis=axes, keepdims=True)
    scale = int8_scale(absmax)
    return QuantizedPool(values=round_int8(x32 / scale, key), scale=scale)


def dequantize_stack(values: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return values.astype(jnp.float32) * scale


def quantize_like(x: jnp.ndarray, scale_shape, *, key=None) -> QuantizedPool:
    """Quantize with the absmax reduced over the axes ``scale_shape`` marks
    as broadcast (size-1) — the general form behind both the pooled
    per-block scales ``(N, 1, ..., 1)`` and the whole-leaf scalar scales
    ``(1, ..., 1)`` of the diag-fallback accumulators."""
    x32 = x.astype(jnp.float32)
    axes = tuple(i for i, n in enumerate(scale_shape) if n == 1)
    absmax = jnp.max(jnp.abs(x32), axis=axes, keepdims=True)
    scale = int8_scale(absmax)
    return QuantizedPool(values=round_int8(x32 / scale, key), scale=scale)


# ---------------------------------------------------------------------------
# Pool-level storage transform


def _int8_eligible(meta: api.StateMeta, value) -> bool:
    """int8 covers the per-block *matrix* factors (ndim >= 3 with the pool
    dim) — see module docstring for why vectors/scalars stay fp32."""
    return meta.role == "second_moment" and value.ndim >= 3


def quantize_leaf_state(stats: Any, dtype: str, *, key=None) -> Any:
    """Storage layout for a *per-leaf* (non-pooled) stats tree — the diag-
    fallback accumulators of core/api.py.  Unlike ``quantize_pool`` there is
    no leading blocks dim, so int8 uses one whole-array absmax scale of
    shape ``(1,) * ndim`` per leaf; the scale is tagged
    ``shard="replicate"`` (a scalar — the int8 values keep the owning
    parameter's sharding via ``param_index``)."""
    if dtype == "fp32":
        return stats
    if dtype == "bf16":
        return api.map_with_meta(
            lambda meta, v: v.astype(jnp.bfloat16)
            if meta is not None and meta.role == "second_moment" else v,
            stats)
    if dtype != "int8":
        raise ValueError(f"unknown second_moment_dtype {dtype!r}; expected "
                         f"one of {SECOND_MOMENT_DTYPES}")

    flat, treedef = jax.tree.flatten(stats, is_leaf=_is_node)
    out = []
    for i, x in enumerate(flat):
        if isinstance(x, api.Tagged) and x.meta.role == "second_moment":
            sub = None if key is None else jax.random.fold_in(key, i)
            qp = quantize_like(x.value, (1,) * x.value.ndim, key=sub)
            scale_meta = dataclasses.replace(x.meta, shard="replicate")
            out.append(QuantizedPool(values=api.Tagged(qp.values, x.meta),
                                     scale=api.Tagged(qp.scale, scale_meta)))
        else:
            out.append(x)
    return jax.tree.unflatten(treedef, out)


def quantize_pool(stats: Any, dtype: str, *, key=None) -> Any:
    """Tagged stats tree (one pool stack) -> its storage-layout tree."""
    if dtype == "fp32":
        return stats
    if dtype == "bf16":
        return api.map_with_meta(
            lambda meta, v: v.astype(jnp.bfloat16)
            if meta is not None and meta.role == "second_moment" else v,
            stats)
    if dtype != "int8":
        raise ValueError(f"unknown second_moment_dtype {dtype!r}; expected "
                         f"one of {SECOND_MOMENT_DTYPES}")

    flat, treedef = jax.tree.flatten(stats, is_leaf=_is_node)
    out = []
    for i, x in enumerate(flat):
        if isinstance(x, api.Tagged) and _int8_eligible(x.meta, x.value):
            sub = None if key is None else jax.random.fold_in(key, i)
            qp = quantize_stack(x.value, key=sub)
            out.append(QuantizedPool(values=api.Tagged(qp.values, x.meta),
                                     scale=api.Tagged(qp.scale, x.meta)))
        else:
            out.append(x)
    return jax.tree.unflatten(treedef, out)


def dequantize_pool(stats: Any) -> Any:
    """Storage-layout tree -> plain untagged f32 compute tree.

    The engine calls this at the batched-method boundary; for an all-fp32
    tree it is exactly ``api.untag`` (the f32->f32 cast is a no-op), keeping
    the default path bitwise-identical.
    """
    def one(x):
        if isinstance(x, QuantizedPool):
            return dequantize_stack(api.untag(x.values), api.untag(x.scale))
        if isinstance(x, api.Tagged):
            if x.meta.role == "second_moment":
                return x.value.astype(jnp.float32)
            return x.value
        return x
    return jax.tree.map(one, stats, is_leaf=_is_node)


def compute_view(stats: Any) -> Any:
    """Storage-layout tree -> compute tree that KEEPS the int8 containers.

    The fused quantized-compute engine path (core/api.py,
    ``quantized_epilogue``) uses this instead of :func:`dequantize_pool`:
    each ``QuantizedPool`` survives as an *untagged* ``QuantizedPool`` of
    plain arrays, so the batched FD methods (core/fd.py) dispatch to the
    fused int8 kernels and the f32 factor stack is never materialized at
    the boundary.  Non-quantized leaves behave exactly like
    ``dequantize_pool`` (bf16 second moments upcast to f32, everything
    else untagged verbatim).
    """
    def one(x):
        if isinstance(x, QuantizedPool):
            return QuantizedPool(values=api.untag(x.values),
                                 scale=api.untag(x.scale))
        if isinstance(x, api.Tagged):
            if x.meta.role == "second_moment":
                return x.value.astype(jnp.float32)
            return x.value
        return x
    return jax.tree.map(one, stats, is_leaf=_is_node)


def requantize_pool(template: Any, raw: Any, *, key=None) -> Any:
    """Computed tree -> storage layout, with tags/containers from
    ``template`` (the previous state).  ``raw`` must be congruent with the
    dequantized structure — each QuantizedPool/Tagged node position holds
    one array, OR (fused quantized-compute path) an already-quantized
    ``QuantizedPool`` produced in-kernel, which passes through with only
    the template's tags re-attached (no second rounding).
    """
    flat_t, treedef = jax.tree.flatten(template, is_leaf=_is_node)
    flat_r = treedef.flatten_up_to(raw)
    out = []
    for i, (t, r) in enumerate(zip(flat_t, flat_r)):
        if isinstance(t, QuantizedPool):
            if isinstance(r, QuantizedPool):
                # fused epilogue already quantized this stack in-kernel:
                # re-tag and store as-is (re-quantizing would double-round)
                out.append(QuantizedPool(
                    values=api.Tagged(api.untag(r.values), t.values.meta),
                    scale=api.Tagged(api.untag(r.scale), t.scale.meta)))
                continue
            sub = None if key is None else jax.random.fold_in(key, i)
            # absmax axes follow the template's scale shape: (N, 1, ..., 1)
            # per-block scales for pools, (1, ..., 1) whole-array scales for
            # diag-fallback leaves — for pools this is exactly what
            # quantize_stack does (bitwise-identical path).
            qp = quantize_like(r, t.scale.value.shape, key=sub)
            out.append(QuantizedPool(
                values=api.Tagged(qp.values, t.values.meta),
                scale=api.Tagged(qp.scale, t.scale.meta)))
        elif isinstance(t, api.Tagged):
            out.append(api.Tagged(r.astype(t.value.dtype), t.meta))
        else:
            out.append(r)
    return jax.tree.unflatten(treedef, out)
