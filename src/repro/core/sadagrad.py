"""Sketchy AdaGrad (paper Alg. 2) and the Appendix-A convex competitors.

These operate on a single d-dimensional decision vector in the OCO setting
(Sec. 2) — used by the convex benchmarks that re-create paper Tbl. 3 / Obs. 2.
All learners expose:  state = init(d);  x, state = step(state, x, g, lr).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.fd import FDState, fd_apply_inverse_root, fd_init, fd_update


class SAdaGradState(NamedTuple):
    sketch: FDState


def sadagrad_init(d: int, ell: int) -> SAdaGradState:
    return SAdaGradState(sketch=fd_init(d, ell))


def sadagrad_step(state: SAdaGradState, x, g, lr):
    """Alg. 2: sketch, compensate with rho_{1:t} I, precondition by -1/2 root."""
    sketch = fd_update(state.sketch, g[:, None], beta2=1.0)
    direction = fd_apply_inverse_root(sketch, g[:, None], exponent=-0.5,
                                      eps=0.0)[:, 0]
    return x - lr * direction, SAdaGradState(sketch=sketch)


class AdaFDState(NamedTuple):
    sketch: FDState


def adafd_init(d: int, ell: int) -> AdaFDState:
    return AdaFDState(sketch=fd_init(d, ell))


def adafd_step(state: AdaFDState, x, g, lr, delta: float):
    """Ada-FD [26]: FD sketch + *fixed* diagonal delta I (no compensation).

    Provably Omega(T^{3/4}) on the Obs. 2 stream — the pathology S-AdaGrad's
    dynamic compensation fixes.
    """
    sketch = fd_update(state.sketch, g[:, None], beta2=1.0)
    # fixed delta regularizer; ignore accumulated rho entirely
    no_comp = FDState(sketch.eigvecs, sketch.eigvals,
                      jnp.zeros_like(sketch.rho))
    direction = fd_apply_inverse_root(no_comp, g[:, None], exponent=-0.5,
                                      eps=delta)[:, 0]
    return x - lr * direction, AdaFDState(sketch=sketch)


class FDSONState(NamedTuple):
    sketch: FDState


def fdson_init(d: int, ell: int) -> FDSONState:
    return FDSONState(sketch=fd_init(d, ell))


def fdson_step(state: FDSONState, x, g, lr, delta: float):
    """FD-SON [27]: Online-Newton-Step-style inverse (exponent -1) on the FD
    sketch with fixed delta I."""
    sketch = fd_update(state.sketch, g[:, None], beta2=1.0)
    no_comp = FDState(sketch.eigvecs, sketch.eigvals, jnp.zeros_like(sketch.rho))
    direction = fd_apply_inverse_root(no_comp, g[:, None], exponent=-1.0,
                                      eps=delta)[:, 0]
    return x - lr * direction, FDSONState(sketch=sketch)


class RFDSONState(NamedTuple):
    sketch: FDState


def rfdson_init(d: int, ell: int) -> RFDSONState:
    return RFDSONState(sketch=fd_init(d, ell))


def rfdson_step(state: RFDSONState, x, g, lr):
    """RFD-SON [43] (delta=0 "RFD_0" variant): robust FD compensates with
    rho_{1:t}/2 in the ONS-style inverse."""
    sketch = fd_update(state.sketch, g[:, None], beta2=1.0)
    half = FDState(sketch.eigvecs, sketch.eigvals, sketch.rho * 0.5)
    direction = fd_apply_inverse_root(half, g[:, None], exponent=-1.0,
                                      eps=0.0)[:, 0]
    return x - lr * direction, RFDSONState(sketch=sketch)


class DiagAdaGradState(NamedTuple):
    acc: jnp.ndarray


def adagrad_init(d: int) -> DiagAdaGradState:
    return DiagAdaGradState(acc=jnp.zeros((d,)))


def adagrad_step(state: DiagAdaGradState, x, g, lr):
    acc = state.acc + jnp.square(g)
    return x - lr * g * jax.lax.rsqrt(acc + 1e-12), DiagAdaGradState(acc=acc)


def ogd_init(d: int):
    return ()


def ogd_step(state, x, g, lr):
    return x - lr * g, state


LEARNERS = {
    "s-adagrad": (sadagrad_init, sadagrad_step, {"ell": True, "delta": False}),
    "ada-fd": (adafd_init, adafd_step, {"ell": True, "delta": True}),
    "fd-son": (fdson_init, fdson_step, {"ell": True, "delta": True}),
    "rfd-son": (rfdson_init, rfdson_step, {"ell": True, "delta": False}),
    "adagrad": (adagrad_init, adagrad_step, {"ell": False, "delta": False}),
    "ogd": (ogd_init, ogd_step, {"ell": False, "delta": False}),
}
