"""Sketchy AdaGrad (paper Alg. 2) and the Appendix-A convex competitors.

These operate on a single d-dimensional decision vector in the OCO setting
(Sec. 2) — used by the convex benchmarks that re-create paper Tbl. 3 / Obs. 2.
All learners expose:  state = init(d);  x, state = step(state, x, g, lr).

S-AdaGrad itself is expressed through the shared ``scale_by_preconditioner``
engine: a left-only FD sketch over the (d, 1) gradient column with exponent
-1/2, no EMA (beta2=1), no grafting, refreshed every step.  The remaining
Appendix-A competitors (Ada-FD, FD-SON, RFD-SON) keep their direct FD forms —
they exist only as paper baselines.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import api, blocking
from repro.core.fd import FDState, fd_apply_inverse_root, fd_init, fd_update


@dataclasses.dataclass(frozen=True)
class SAdaGradPreconditioner:
    """Alg. 2: FD-sketch the gradient stream, compensate with rho_{1:t} I,
    precondition by the -1/2 root.  ``ell`` is used only at init; ``beta2``
    is the FD EMA decay (paper Obs. 6) — 1.0 is the unweighted regret
    setting, < 1 forgets old mass, which is what the serve-time adaptation
    loop wants under distribution drift (serve/adapt.py).  It may be a
    traced scalar (injected hyperparameter): it only enters arithmetic."""
    ell: int = 0
    beta2: Any = 1.0

    diagonal: ClassVar[bool] = False

    def init_block(self, info: blocking.BlockInfo) -> FDState:
        st = fd_init(info.bs_m, min(self.ell, info.bs_m))
        return FDState(*(api.tag(x, "second_moment", blocked=True)
                         for x in st))

    def update_stats(self, state, G, *, count):
        return state

    def refresh(self, state, G, *, count):
        return fd_update(state, G, beta2=self.beta2)

    def precondition(self, state, G, *, count):
        return fd_apply_inverse_root(state, G, exponent=-0.5, eps=0.0)


def sadagrad(ell: int, beta2=1.0) -> "api.GradientTransformation":
    """S-AdaGrad as a composable direction transform on the shared engine."""
    return api.scale_by_preconditioner(
        SAdaGradPreconditioner(ell, beta2),
        api.EngineConfig(block_size=1 << 30, beta2=1.0, update_every=1,
                         graft="none", treat_vectors_as_columns=True))


# Update structure never depends on ell (it is read off the state shapes), so
# one transform instance serves every step call; jitted since the engine step
# is pure and shape-stable (compiles once per (d, ell)).
_SADAGRAD_STEP_TX = sadagrad(0)


@jax.jit
def _sadagrad_jit_step(opt_state, x, g, lr):
    direction, opt = _SADAGRAD_STEP_TX.update(g, opt_state)
    return x - lr * direction, opt


class SAdaGradState(NamedTuple):
    opt: Any    # engine PrecondState

    @property
    def sketch(self) -> FDState:
        """The (d, ell) FD sketch, unbatched (analysis/back-compat)."""
        raw = api.pool_stats(self.opt)   # single (d, 1) group for a d-vector
        return jax.tree.map(lambda x: x[0], raw)


def sadagrad_init(d: int, ell: int) -> SAdaGradState:
    return SAdaGradState(opt=sadagrad(ell).init(jnp.zeros((d,))))


def sadagrad_step(state: SAdaGradState, x, g, lr):
    new_x, opt = _sadagrad_jit_step(state.opt, x, g, lr)
    return new_x, SAdaGradState(opt=opt)


class AdaFDState(NamedTuple):
    sketch: FDState


def adafd_init(d: int, ell: int) -> AdaFDState:
    return AdaFDState(sketch=fd_init(d, ell))


@jax.jit
def adafd_step(state: AdaFDState, x, g, lr, delta: float):
    """Ada-FD [26]: FD sketch + *fixed* diagonal delta I (no compensation).

    Provably Omega(T^{3/4}) on the Obs. 2 stream — the pathology S-AdaGrad's
    dynamic compensation fixes.
    """
    sketch = fd_update(state.sketch, g[:, None], beta2=1.0)
    # fixed delta regularizer; ignore accumulated rho entirely
    no_comp = FDState(sketch.eigvecs, sketch.eigvals,
                      jnp.zeros_like(sketch.rho))
    direction = fd_apply_inverse_root(no_comp, g[:, None], exponent=-0.5,
                                      eps=delta)[:, 0]
    return x - lr * direction, AdaFDState(sketch=sketch)


class FDSONState(NamedTuple):
    sketch: FDState


def fdson_init(d: int, ell: int) -> FDSONState:
    return FDSONState(sketch=fd_init(d, ell))


@jax.jit
def fdson_step(state: FDSONState, x, g, lr, delta: float):
    """FD-SON [27]: Online-Newton-Step-style inverse (exponent -1) on the FD
    sketch with fixed delta I."""
    sketch = fd_update(state.sketch, g[:, None], beta2=1.0)
    no_comp = FDState(sketch.eigvecs, sketch.eigvals, jnp.zeros_like(sketch.rho))
    direction = fd_apply_inverse_root(no_comp, g[:, None], exponent=-1.0,
                                      eps=delta)[:, 0]
    return x - lr * direction, FDSONState(sketch=sketch)


class RFDSONState(NamedTuple):
    sketch: FDState


def rfdson_init(d: int, ell: int) -> RFDSONState:
    return RFDSONState(sketch=fd_init(d, ell))


@jax.jit
def rfdson_step(state: RFDSONState, x, g, lr):
    """RFD-SON [43] (delta=0 "RFD_0" variant): robust FD compensates with
    rho_{1:t}/2 in the ONS-style inverse."""
    sketch = fd_update(state.sketch, g[:, None], beta2=1.0)
    half = FDState(sketch.eigvecs, sketch.eigvals, sketch.rho * 0.5)
    direction = fd_apply_inverse_root(half, g[:, None], exponent=-1.0,
                                      eps=0.0)[:, 0]
    return x - lr * direction, RFDSONState(sketch=sketch)


class DiagAdaGradState(NamedTuple):
    acc: jnp.ndarray


def adagrad_init(d: int) -> DiagAdaGradState:
    return DiagAdaGradState(acc=jnp.zeros((d,)))


@jax.jit
def adagrad_step(state: DiagAdaGradState, x, g, lr):
    acc = state.acc + jnp.square(g)
    return x - lr * g * jax.lax.rsqrt(acc + 1e-12), DiagAdaGradState(acc=acc)


def ogd_init(d: int):
    return ()


def ogd_step(state, x, g, lr):
    return x - lr * g, state


LEARNERS = {
    "s-adagrad": (sadagrad_init, sadagrad_step, {"ell": True, "delta": False}),
    "ada-fd": (adafd_init, adafd_step, {"ell": True, "delta": True}),
    "fd-son": (fdson_init, fdson_step, {"ell": True, "delta": True}),
    "rfd-son": (rfdson_init, rfdson_step, {"ell": True, "delta": False}),
    "adagrad": (adagrad_init, adagrad_step, {"ell": False, "delta": False}),
    "ogd": (ogd_init, ogd_step, {"ell": False, "delta": False}),
}
