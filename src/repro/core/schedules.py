"""Learning-rate schedules (paper App. C: linear warmup -> cosine decay)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    def sched(count):
        return jnp.asarray(value, jnp.float32)

    return sched


def warmup_cosine(peak: float, total_steps: int, warmup_frac: float = 0.05,
                  end_value: float = 0.0):
    """Linear warmup for ``warmup_frac`` of training, then cosine decay to 0.

    Matches the paper's setup: warmup transition 5% of the way into training,
    cosine quarter-period set to the number of training steps.
    """
    warmup_steps = max(int(total_steps * warmup_frac), 1)

    def sched(count):
        count = jnp.asarray(count, jnp.float32)
        warm = peak * count / warmup_steps
        decay_steps = max(total_steps - warmup_steps, 1)
        frac = jnp.clip((count - warmup_steps) / decay_steps, 0.0, 1.0)
        cos = end_value + (peak - end_value) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(count < warmup_steps, warm, cos)

    return sched
