"""Full (blocked) Shampoo baseline — the paper's primary comparison.

Kronecker-factored preconditioning with *dense* per-block factors
L (bm x bm), R (bn x bn), EMA statistics, inverse 4th roots recomputed every
``root_every`` steps via eigh (the ``eigh=True`` path the paper uses, App. E).
Second-moment memory is O(bm^2 + bn^2) per block — what Sketchy reduces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import blocking
from repro.core.transform import GradientTransformation


@dataclasses.dataclass(frozen=True)
class ShampooConfig:
    block_size: int = 1024
    beta2: float = 0.999
    root_every: int = 10            # paper: preconditioning_compute_steps=10
    start_preconditioning_step: int = 0
    matrix_eps: float = 1e-6
    graft_eps: float = 1e-8
    graft: str = "rmsprop_normalized"
    state_dtype: Any = jnp.float32


class ShampooMatrixLeaf(NamedTuple):
    L: jnp.ndarray       # (S, bm, bm)
    R: jnp.ndarray       # (S, bn, bn)
    PL: jnp.ndarray      # cached L^{-1/4}
    PR: jnp.ndarray      # cached R^{-1/4}
    graft_acc: jnp.ndarray


class ShampooDiagLeaf(NamedTuple):
    acc: jnp.ndarray


class ShampooState(NamedTuple):
    count: jnp.ndarray
    leaves: tuple


def _inv_root(mats: jnp.ndarray, eps: float, power: float) -> jnp.ndarray:
    """(S, d, d) PSD -> (M + eps*I)^{power} via batched eigh."""
    def one(m):
        d = m.shape[-1]
        lam, V = jnp.linalg.eigh(m + eps * jnp.eye(d, dtype=m.dtype))
        lam = jnp.maximum(lam, eps)
        return (V * jnp.power(lam, power)[None, :]) @ V.T

    return jax.vmap(one)(mats)


def shampoo(cfg: ShampooConfig = ShampooConfig()) -> GradientTransformation:
    from repro.core.sketchy import _graft_direction, SketchyConfig

    graft_cfg = SketchyConfig(beta2=cfg.beta2, graft=cfg.graft,
                              graft_eps=cfg.graft_eps)

    def init_leaf(p):
        info = blocking.analyze(p.shape, cfg.block_size)
        if info.kind == "diag":
            return ShampooDiagLeaf(acc=jnp.zeros(p.shape, cfg.state_dtype))
        S = info.num_blocks
        eye_m = jnp.eye(info.bs_m, dtype=cfg.state_dtype)
        eye_n = jnp.eye(info.bs_n, dtype=cfg.state_dtype)
        zeros = lambda d: jnp.zeros((S, d, d), cfg.state_dtype)
        return ShampooMatrixLeaf(
            L=zeros(info.bs_m), R=zeros(info.bs_n),
            PL=jnp.broadcast_to(eye_m, (S, info.bs_m, info.bs_m)),
            PR=jnp.broadcast_to(eye_n, (S, info.bs_n, info.bs_n)),
            graft_acc=jnp.zeros(p.shape, cfg.state_dtype),
        )

    def init_fn(params):
        leaves = tuple(init_leaf(p) for p in jax.tree.leaves(params))
        return ShampooState(count=jnp.zeros([], jnp.int32), leaves=leaves)

    def update_leaf(g, st, count):
        g32 = g.astype(jnp.float32)
        info = blocking.analyze(g.shape, cfg.block_size)
        if info.kind == "diag":
            acc = cfg.beta2 * st.acc + (1.0 - cfg.beta2) * jnp.square(g32)
            return (g32 * jax.lax.rsqrt(acc + cfg.graft_eps)).astype(g.dtype), \
                ShampooDiagLeaf(acc=acc)

        gb = blocking.to_blocks(g32, info)
        # statistics every step (classic Shampoo; FD variant is restricted to
        # every 10th — see paper §6 "more difficult setting for S-Shampoo")
        # un-normalized EMA (distributed-Shampoo convention; matches the
        # FD recursion of Obs. 6 so rank>=dim recovers Shampoo exactly)
        L = cfg.beta2 * st.L + jnp.einsum("sij,skj->sik", gb, gb)
        R = cfg.beta2 * st.R + jnp.einsum("sji,sjk->sik", gb, gb)

        def refresh(_):
            return _inv_root(L, cfg.matrix_eps, -0.25), _inv_root(R, cfg.matrix_eps, -0.25)

        do_roots = (count % cfg.root_every) == 0
        PL, PR = jax.lax.cond(do_roots, refresh, lambda _: (st.PL, st.PR), None)

        pb = jnp.einsum("sij,sjk,skl->sil", PL, gb, PR)
        precond = blocking.from_blocks(pb, info)

        graft_dir, new_acc = _graft_direction(g32, st.graft_acc, graft_cfg)
        if cfg.graft != "none":
            precond = precond * (jnp.linalg.norm(graft_dir)
                                 / (jnp.linalg.norm(precond) + 1e-16))
        use_precond = count >= cfg.start_preconditioning_step
        direction = jnp.where(use_precond, precond, graft_dir)
        return direction.astype(g.dtype), ShampooMatrixLeaf(L, R, PL, PR, new_acc)

    def update_fn(updates, state, params=None):
        del params
        flat, treedef = jax.tree.flatten(updates)
        out, leaves = [], []
        for g, st in zip(flat, state.leaves):
            d, ns = update_leaf(g, st, state.count)
            out.append(d)
            leaves.append(ns)
        return (jax.tree.unflatten(treedef, out),
                ShampooState(count=state.count + 1, leaves=tuple(leaves)))

    return GradientTransformation(init_fn, update_fn)


def second_moment_bytes(state: ShampooState) -> int:
    total = 0
    for leaf in state.leaves:
        if isinstance(leaf, ShampooMatrixLeaf):
            total += leaf.L.size * leaf.L.dtype.itemsize
            total += leaf.R.size * leaf.R.dtype.itemsize
        else:
            total += leaf.acc.size * leaf.acc.dtype.itemsize
    return total
