"""Full (blocked) Shampoo baseline — the paper's primary comparison — as a
small ``Preconditioner`` on the shared ``scale_by_preconditioner`` engine.

Kronecker-factored preconditioning with *dense* per-block factors
L (bm x bm), R (bn x bn), EMA statistics accumulated every step, inverse 4th
roots recomputed every ``root_every`` steps via eigh (the ``eigh=True`` path
the paper uses, App. E).  Second-moment memory is O(bm^2 + bn^2) per block —
what Sketchy reduces.  Blocking, grafting, the diagonal fallback, and gating
live in the engine (core/api.py).

Shampoo's L/R statistic updates are the same Gram contraction as the FD
update (cf. Morwani et al., *A New Perspective on Shampoo's Preconditioner*):
L += G G^T is the Gram of G^T and R += G^T G the Gram of G.  The engine
injects its resolved ``KernelSet`` into ``kernels``, so the batched methods
route both contractions through the grid-over-N batched gram kernel — the
same kernel path Sketchy uses, one call per packed pool stack.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import api, blocking
from repro.core.transform import GradientTransformation
from repro.kernels.registry import KernelSet


@dataclasses.dataclass(frozen=True)
class ShampooConfig:
    block_size: int = 1024
    beta2: float = 0.999
    root_every: int = 10            # paper: preconditioning_compute_steps=10
    start_preconditioning_step: int = 0
    matrix_eps: float = 1e-6
    graft_eps: float = 1e-8
    diag_eps: Optional[float] = None    # diag-fallback damping (None => graft_eps)
    graft: str = "rmsprop_normalized"
    refresh_schedule: str = "synchronized"  # synchronized | staggered
    # "inline" (parity default) | "async": root recompute launched at step t
    # commits at t+1 via the engine's pending slot (core/api.py)
    refresh_mode: str = "inline"
    profile_annotations: bool = False
    state_dtype: Any = jnp.float32
    # kernel backend for the pooled stat-update Grams: "pallas"|"xla"|"auto"
    kernel_backend: str = "auto"
    # storage dtype for the pooled L/R statistics between steps
    # (core/quantize.py): "fp32" (bitwise parity) | "bf16" | "int8"
    second_moment_dtype: str = "fp32"


class ShampooBlockStats(NamedTuple):
    L: jnp.ndarray       # (bm, bm) EMA statistic
    R: jnp.ndarray       # (bn, bn)
    PL: jnp.ndarray      # cached L^{-1/4}
    PR: jnp.ndarray      # cached R^{-1/4}


def _inv_root(m: jnp.ndarray, eps: float, power: float) -> jnp.ndarray:
    """(..., d, d) PSD -> (M + eps*I)^{power} via eigh (batch-polymorphic)."""
    d = m.shape[-1]
    lam, V = jnp.linalg.eigh(m + eps * jnp.eye(d, dtype=m.dtype))
    lam = jnp.maximum(lam, eps)
    return jnp.matmul(V * jnp.power(lam, power)[..., None, :],
                      jnp.swapaxes(V, -1, -2))


@dataclasses.dataclass(frozen=True)
class ShampooPreconditioner:
    """Dense L/R factors + cached inverse roots (per block).

    ``kernels`` is injected by the engine (``EngineConfig.kernel_backend``);
    the batched methods run once per packed ``(N, bs_m, bs_n)`` pool stack.
    """
    cfg: ShampooConfig
    kernels: Optional[KernelSet] = None

    diagonal: ClassVar[bool] = False

    def init_block(self, info: blocking.BlockInfo) -> ShampooBlockStats:
        dt = self.cfg.state_dtype
        return ShampooBlockStats(
            L=api.tag(jnp.zeros((info.bs_m, info.bs_m), dt),
                      "second_moment", blocked=True),
            R=api.tag(jnp.zeros((info.bs_n, info.bs_n), dt),
                      "second_moment", blocked=True),
            PL=api.tag(jnp.eye(info.bs_m, dtype=dt),
                       "preconditioner", blocked=True),
            PR=api.tag(jnp.eye(info.bs_n, dtype=dt),
                       "preconditioner", blocked=True))

    # ------------------------------------------------- per-block (reference)

    def update_stats(self, state, G, *, count):
        # statistics every step (classic Shampoo; the FD variant is
        # restricted to every 10th — see paper §6 "more difficult setting")
        # un-normalized EMA (distributed-Shampoo convention; matches the
        # FD recursion of Obs. 6 so rank>=dim recovers Shampoo exactly)
        return ShampooBlockStats(
            L=self.cfg.beta2 * state.L + G @ G.T,
            R=self.cfg.beta2 * state.R + G.T @ G,
            PL=state.PL, PR=state.PR)

    def refresh(self, state, G, *, count):
        return ShampooBlockStats(
            L=state.L, R=state.R,
            PL=_inv_root(state.L, self.cfg.matrix_eps, -0.25),
            PR=_inv_root(state.R, self.cfg.matrix_eps, -0.25))

    def precondition(self, state, G, *, count):
        return state.PL @ G @ state.PR

    # ------------------------------------------- pooled-stack (kernel path)

    def update_stats_batched(self, state, G, *, count):
        # L += gram(G^T), R += gram(G): the FD paper's tall-skinny Gram,
        # batched over the pool dim by the injected kernel set.
        if self.kernels is not None:
            L_inc = self.kernels.batched_gram(jnp.swapaxes(G, -1, -2))
            R_inc = self.kernels.batched_gram(G)
        else:
            L_inc = jnp.matmul(G, jnp.swapaxes(G, -1, -2))
            R_inc = jnp.matmul(jnp.swapaxes(G, -1, -2), G)
        return ShampooBlockStats(
            L=self.cfg.beta2 * state.L + L_inc,
            R=self.cfg.beta2 * state.R + R_inc,
            PL=state.PL, PR=state.PR)

    def refresh_batched(self, state, G, *, count):
        return ShampooBlockStats(
            L=state.L, R=state.R,
            PL=_inv_root(state.L, self.cfg.matrix_eps, -0.25),
            PR=_inv_root(state.R, self.cfg.matrix_eps, -0.25))

    def precondition_batched(self, state, G, *, count):
        return jnp.matmul(jnp.matmul(state.PL, G), state.PR)


def shampoo(cfg: ShampooConfig = ShampooConfig()) -> GradientTransformation:
    return api.scale_by_preconditioner(
        ShampooPreconditioner(cfg),
        api.EngineConfig(
            block_size=cfg.block_size, beta2=cfg.beta2,
            update_every=cfg.root_every,
            start_preconditioning_step=cfg.start_preconditioning_step,
            graft=cfg.graft, graft_eps=cfg.graft_eps, diag_eps=cfg.diag_eps,
            refresh_schedule=cfg.refresh_schedule,
            refresh_mode=cfg.refresh_mode,
            profile_annotations=cfg.profile_annotations,
            kernel_backend=cfg.kernel_backend,
            second_moment_dtype=cfg.second_moment_dtype,
            state_dtype=cfg.state_dtype))


def second_moment_bytes(state) -> int:
    return api.second_moment_bytes(state)
