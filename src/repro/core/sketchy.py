"""Sketchy Shampoo (paper Alg. 3 + Obs. 6 EMA variant) as a small
``Preconditioner`` on the shared ``scale_by_preconditioner`` engine.

Per matrix block (paper §3.4 blocking, default 1024):
  every ``update_every`` steps (paper observes only every 10th gradient —
  the "harder setting" of §6):
      (rho_L, L-sketch) <- FD-update(beta2 * L-sketch, G G^T)
      (rho_R, R-sketch) <- FD-update(beta2 * R-sketch, G^T G)
  every step:
      P = (L-sketch + (rho_L+eps) I)^{-1/4}  G  (R-sketch + (rho_R+eps) I)^{-1/4}
computed entirely in factored (U, s, rho) form — the d x d preconditioner is
never materialized and the second-moment state is O((m+n) * ell) per block
instead of O(m^2 + n^2) (Shampoo) or O(mn) (Adam).

Blocking, the diagonal (RMSProp) path for vectors/scalars, grafting (paper
App. C: RMSPROP_NORMALIZED), and the ``update_every`` /
``start_preconditioning_step`` gating all live in the engine (core/api.py);
this module only supplies the FD sketch pair.  The engine injects its
resolved ``KernelSet`` (``kernel_backend`` knob: pallas | xla | auto) into
``kernels``; the ``*_batched`` methods — the pooled hot path — route the
Gram and the fused low-rank apply through the grid-over-N batched kernels,
one call per packed pool stack instead of a vmap over single-block kernels.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, ClassVar, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import api, blocking, pool
from repro.core.fd import (FDState, fd_apply_inverse_root,
                           fd_apply_inverse_root_batched, fd_init,
                           fd_resize_batched, fd_update, fd_update_batched)
from repro.core.transform import GradientTransformation
from repro.kernels.registry import KernelSet

RANK_POLICIES = ("static", "rho_greedy")

DEFAULT_RANK = 256                  # paper fixes 256 (untuned)


@dataclasses.dataclass(frozen=True)
class RankBudget:
    """The rank API: one fixed total sketch-rank budget over all blocks.

    Every pooled block stores its FD sketch pair at *capacity*
    ``ell = min(max_k, dim)`` — the packed ``(N, d, ell)`` stacks (and
    therefore ``second_moment_bytes``) are sized by ``max_k`` alone — but
    each block's *active* rank ``k_b`` is a masked ladder prefix, with
    ``sum_b k_b == total`` held fixed.

    Policies:
      * ``"static"`` — every block keeps ``k_b`` at capacity forever;
        bitwise-identical to the pre-budget engine (the deprecated
        ``SketchyConfig(rank=r)`` spelling maps here with
        ``min_k == max_k == r``).
      * ``"rho_greedy"`` — at refresh boundaries (every
        ``realloc_every * update_every`` steps) the budget is re-poured
        across blocks by descending escaped-mass pressure
        ``rho / (trace + rho)``: blocks whose sketch is dropping the most
        mass grow (zero columns unmask), blocks that are over-provisioned
        shrink via exact Robust-FD deflation (dropped eigenvalue mass
        folds into ``rho``, preserving the per-block FD bound).

    ``total=None`` resolves to ``N_blocks * max_k`` at init (full capacity,
    useful with ``min_k`` to carve slack); an explicit total must satisfy
    ``N * min_k <= total <= N * max_k``.
    """
    total: Optional[int] = None
    min_k: int = 1
    max_k: int = DEFAULT_RANK
    realloc_every: int = 1          # in refresh windows (update_every steps)
    policy: str = "static"          # static | rho_greedy

    def __post_init__(self):
        if self.policy not in RANK_POLICIES:
            raise ValueError(f"unknown RankBudget policy {self.policy!r}; "
                             f"expected one of {RANK_POLICIES}")
        if not (1 <= self.min_k <= self.max_k):
            raise ValueError(f"need 1 <= min_k <= max_k, got "
                             f"min_k={self.min_k} max_k={self.max_k}")
        if self.realloc_every < 1:
            raise ValueError(f"realloc_every must be >= 1, got "
                             f"{self.realloc_every}")

    def resolve_total(self, num_blocks: int) -> int:
        """Concrete ``K_total`` once the model's block count is known."""
        total = self.total if self.total is not None \
            else num_blocks * self.max_k
        if not (num_blocks * self.min_k <= total <= num_blocks * self.max_k):
            raise ValueError(
                f"rank budget total={total} infeasible for {num_blocks} "
                f"blocks with min_k={self.min_k} max_k={self.max_k}: need "
                f"{num_blocks * self.min_k} <= total <= "
                f"{num_blocks * self.max_k}")
        return total


@dataclasses.dataclass(frozen=True)
class SketchyConfig:
    # Deprecated alias for ``rank_budget=RankBudget(min_k=r, max_k=r,
    # policy="static")``; after construction this field always reads as the
    # normalized capacity ``rank_budget.max_k`` (legacy consumers keep
    # working).  Pass ``rank_budget`` instead.
    rank: Optional[int] = None
    block_size: int = 1024          # paper App. C
    beta2: float = 0.999            # second-moment EMA (paper §5.2)
    update_every: int = 10          # FD observes every k-th gradient (paper §6)
    start_preconditioning_step: int = 0   # paper App. C uses 101 at scale
    matrix_eps: float = 1e-6
    graft_eps: float = 1e-8
    diag_eps: Optional[float] = None      # diag-fallback damping (None => graft_eps)
    graft: str = "rmsprop_normalized"     # rmsprop_normalized | rmsprop | none
    refresh_schedule: str = "synchronized"  # synchronized | staggered
    # "inline" (parity default) | "async": launch the FD refresh at step t
    # from the just-updated stats, commit it at t+1 — the eigh and the
    # butterfly merge rounds leave the update direction's critical path
    # (engine refresh pipeline, core/api.py)
    refresh_mode: str = "inline"
    # profiling spans around the engine phases (core/api.py _span)
    profile_annotations: bool = False
    exponent: float = -0.25         # per-side inverse root (Alg. 3)
    state_dtype: Any = jnp.float32
    # kernel backend for the pooled hot path (engine-resolved KernelSet):
    # "pallas" | "xla" | "auto" — replaces the old private use_kernels flag
    kernel_backend: str = "auto"
    # storage dtype for the pooled FD sketches between steps
    # (core/quantize.py): "fp32" (bitwise parity) | "bf16" | "int8"
    second_moment_dtype: str = "fp32"
    # second-moment maintenance across data-parallel shards
    # (src/repro/distributed/): "replicated" (parity default) | "sharded"
    # (local FD updates + log-depth butterfly sketch merge over stats_axis
    # at refresh time)
    stats_reduction: str = "replicated"
    stats_axis: str = "data"
    # exchange precision for the merge wire (sketch_merge.pack_wire):
    # "int8" (default, ~(ell-1)*d int8 per block per round) | "fp32"
    # (exact merge — the FD error bound holds with no quantization slack)
    stats_wire_dtype: str = "int8"
    # fused int8 compute (core/api.py): "auto" (on when second_moment_dtype
    # is int8, the pallas backend is resolved, and stats are replicated) |
    # "off" (always dequantize at the boundary) | "on" (force; any backend)
    quantized_epilogue: str = "auto"
    # The primary rank spelling: fixed total budget + per-block active-rank
    # policy (see RankBudget).  None => normalized from the deprecated
    # ``rank`` field (or the paper default 256) in __post_init__.
    rank_budget: Optional[RankBudget] = None

    def __post_init__(self):
        budget = self.rank_budget
        if budget is None:
            rank = self.rank
            if rank is not None:
                warnings.warn(
                    "SketchyConfig(rank=...) is deprecated; use "
                    "rank_budget=RankBudget(min_k=r, max_k=r) (see the "
                    "CHANGES.md migration table)",
                    DeprecationWarning, stacklevel=3)
            else:
                rank = DEFAULT_RANK
            budget = RankBudget(min_k=rank, max_k=rank, policy="static")
        elif self.rank is not None and self.rank != budget.max_k:
            raise ValueError(
                f"pass either rank (deprecated) or rank_budget, not both "
                f"(got rank={self.rank}, rank_budget.max_k={budget.max_k})")
        # normalize: cfg.rank always reads as the capacity for legacy
        # consumers (e.g. tests/reference_impls.py reads cfg.rank)
        object.__setattr__(self, "rank_budget", budget)
        object.__setattr__(self, "rank", budget.max_k)


class SketchyBlockStats(NamedTuple):
    """Per-block FD sketch pair; in engine state these are batched over the
    leaf's block stack: eigvecs (S, d, ell), eigvals (S, ell), rho (S,)."""
    left: FDState
    right: FDState


class BudgetedSketchStats(NamedTuple):
    """``SketchyBlockStats`` plus the per-block active-rank vector ``k``
    (rank-budget policies other than static).  ``k`` is shared by both
    sides — the budget counts each block once; per side the effective
    column count is ``min(k_b, ell_side)`` via the masked-rank update."""
    left: FDState
    right: FDState
    k: Any              # Tagged (N,) int32, role="count", label="active_rank"


def _tag_fd(st: FDState) -> FDState:
    # rho / eigvals carry telemetry labels so api.rank_allocation can
    # traverse them without type dispatch
    return FDState(
        eigvecs=api.tag(st.eigvecs, "second_moment", blocked=True),
        eigvals=api.tag(st.eigvals, "second_moment", blocked=True,
                        label="eigvals"),
        rho=api.tag(st.rho, "second_moment", blocked=True, label="rho"))


def _sketch_pressure(fd: FDState) -> jnp.ndarray:
    """(N,) escaped-mass ratio ``rho / (trace + rho)`` — high means this
    block's sketch is dropping mass and is starving for columns."""
    trace = jnp.sum(jnp.maximum(fd.eigvals.astype(jnp.float32), 0.0), axis=-1)
    rho = jnp.maximum(fd.rho.astype(jnp.float32), 0.0)
    return rho / (trace + rho + 1e-30)


@dataclasses.dataclass(frozen=True)
class SketchyPreconditioner:
    """FD sketch pair (paper Alg. 3) — the whole optimizer-specific surface.

    ``kernels`` is injected by the engine (``EngineConfig.kernel_backend``);
    ``None`` means plain jnp.  The batched methods run once per packed
    ``(N, bs_m, bs_n)`` pool stack.
    """
    cfg: SketchyConfig
    kernels: Optional[KernelSet] = None

    diagonal: ClassVar[bool] = False
    # the batched FD methods dispatch on QuantizedPool eigvec stacks
    # (core/fd.py), so the engine's fused int8 mode can hand this
    # preconditioner the storage containers directly
    supports_quantized_compute: ClassVar[bool] = True

    def init_block(self, info: blocking.BlockInfo):
        budget = self.cfg.rank_budget
        ell_l = min(budget.max_k, info.bs_m)
        ell_r = min(budget.max_k, info.bs_n)
        left = _tag_fd(fd_init(info.bs_m, ell_l, self.cfg.state_dtype))
        right = _tag_fd(fd_init(info.bs_n, ell_r, self.cfg.state_dtype))
        if budget.policy == "static":
            return SketchyBlockStats(left=left, right=right)
        # adaptive policies carry a per-block active rank; the engine
        # broadcasts this scalar over the pool dim and finalize_init_pools
        # replaces it with the uniform initial allocation
        k = api.tag(jnp.asarray(budget.min_k, jnp.int32), "count",
                    blocked=True, label="active_rank")
        return BudgetedSketchStats(left=left, right=right, k=k)

    def finalize_init_pools(self, groups, stacks: dict) -> dict:
        """Engine init hook: seed the cross-pool uniform rank allocation.

        ``stacks`` maps group key -> broadcast Tagged stats stack.  The
        budget is global — one ``K_total`` over every block in every pool —
        so the uniform seed is computed over the concatenated block list
        (and feasibility is validated here, the first point where N is
        known)."""
        budget = self.cfg.rank_budget
        if budget.policy == "static":
            return stacks
        ns = [g.num_blocks for g in groups]
        total = budget.resolve_total(sum(ns))
        k_all = pool.uniform_ranks(sum(ns), total, budget.min_k,
                                   budget.max_k)
        out, offset = dict(stacks), 0
        for g, n in zip(groups, ns):
            st = stacks[g.key]
            out[g.key] = st._replace(
                k=api.Tagged(k_all[offset:offset + n], st.k.meta))
            offset += n
        return out

    def realloc_pools(self, groups, stacks: dict) -> dict:
        """Engine refresh-boundary hook: re-pour the fixed rank budget.

        ``stacks`` holds the just-refreshed raw (untagged) stats per group.
        Pressure is the per-block escaped-mass ratio summed over sides;
        the greedy waterfill (core/pool.py) is exact and deterministic, so
        every data-parallel shard computes the identical allocation from
        the merged (replicated) statistics — no extra communication.
        Shrunk blocks fold the dropped eigenvalue mass into ``rho``
        (fd_resize_batched), grown blocks unmask zero columns."""
        budget = self.cfg.rank_budget
        ns = [g.num_blocks for g in groups]
        total = budget.resolve_total(sum(ns))
        pressure = jnp.concatenate([
            _sketch_pressure(stacks[g.key].left)
            + _sketch_pressure(stacks[g.key].right) for g in groups])
        k_all = pool.allocate_ranks(pressure, total=total,
                                    min_k=budget.min_k, max_k=budget.max_k)
        out, offset = dict(stacks), 0
        for g, n in zip(groups, ns):
            st = stacks[g.key]
            k = k_all[offset:offset + n]
            out[g.key] = st._replace(
                left=fd_resize_batched(st.left, k),
                right=fd_resize_batched(st.right, k), k=k)
            offset += n
        return out

    # ------------------------------------------------- per-block (reference)

    def update_stats(self, state, G, *, count):
        return state  # FD observation is the gated refresh, not per-step

    def refresh(self, state, G, *, count):
        return SketchyBlockStats(
            left=fd_update(state.left, G, self.cfg.beta2,
                           kernels=self.kernels),
            right=fd_update(state.right, G.T, self.cfg.beta2,
                            kernels=self.kernels))

    def precondition(self, state, G, *, count):
        tmp = fd_apply_inverse_root(state.left, G,
                                    exponent=self.cfg.exponent,
                                    eps=self.cfg.matrix_eps,
                                    kernels=self.kernels)
        tmpT = fd_apply_inverse_root(state.right, tmp.T,
                                     exponent=self.cfg.exponent,
                                     eps=self.cfg.matrix_eps,
                                     kernels=self.kernels)
        return tmpT.T

    # ------------------------------------------- pooled-stack (kernel path)

    def update_stats_batched(self, state, G, *, count):
        return state

    def refresh_batched(self, state, G, *, count):
        # budgeted stats carry the per-block active rank; the static
        # container has no ``k`` and takes the unmasked (bitwise-pinned)
        # path through fd_update_batched
        active_k = getattr(state, "k", None)
        return state._replace(
            left=fd_update_batched(state.left, G, self.cfg.beta2,
                                   kernels=self.kernels, active_k=active_k),
            right=fd_update_batched(state.right, jnp.swapaxes(G, -1, -2),
                                    self.cfg.beta2, kernels=self.kernels,
                                    active_k=active_k))

    def refresh_sharded_batched(self, state, G, *, count, axis, axis_size):
        """Sharded-statistics refresh (engine ``stats_reduction="sharded"``):
        FD-update both sketch stacks on this shard's LOCAL gradient stack,
        then butterfly-merge each across the data axis so every shard ends
        the refresh holding the identical combined sketch.  Must run inside
        ``shard_map`` with ``axis`` bound (the engine guarantees it).

        The incoming sketch is replicated over the axis (the previous merge
        left it so), and the butterfly *sums* covariances — so the carried
        state is pre-scaled by 1/P to enter the merged total exactly once:
        merged ~= beta2 * S_prev + (1/P) sum_i G_i G_i^T (the engine already
        scaled the local gradient stack by 1/sqrt(P)), which coincides with
        the replicated ``beta2 * S_prev + Gbar Gbar^T`` when shards agree.
        """
        from repro.distributed import reduce as dreduce
        inv = 1.0 / axis_size
        scale = lambda fd: FDState(eigvecs=fd.eigvecs,
                                   eigvals=fd.eigvals * inv,
                                   rho=fd.rho * inv)
        state = state._replace(left=scale(state.left),
                               right=scale(state.right))
        local = self.refresh_batched(state, G, count=count)
        merge = lambda st: dreduce.butterfly_merge_fd(
            st, axis=axis, axis_size=axis_size, kernels=self.kernels,
            wire_dtype=self.cfg.stats_wire_dtype)
        merged = local._replace(left=merge(local.left),
                                right=merge(local.right))
        active_k = getattr(merged, "k", None)
        if active_k is not None:
            # the butterfly re-sketches at full capacity ell, so the merged
            # ladder can spill past the block's active rank — re-mask it,
            # folding the spilled mass into rho (exact Robust-FD deflation)
            merged = merged._replace(
                left=fd_resize_batched(merged.left, active_k),
                right=fd_resize_batched(merged.right, active_k))
        return merged

    def precondition_batched(self, state, G, *, count):
        tmp = fd_apply_inverse_root_batched(
            state.left, G, exponent=self.cfg.exponent,
            eps=self.cfg.matrix_eps, kernels=self.kernels)
        tmpT = fd_apply_inverse_root_batched(
            state.right, jnp.swapaxes(tmp, -1, -2),
            exponent=self.cfg.exponent, eps=self.cfg.matrix_eps,
            kernels=self.kernels)
        return jnp.swapaxes(tmpT, -1, -2)


def sketchy(cfg: SketchyConfig = SketchyConfig()) -> GradientTransformation:
    """S-Shampoo direction transform (emits a descent direction, no lr)."""
    budget = cfg.rank_budget
    realloc_every = budget.realloc_every if budget.policy != "static" else 0
    return api.scale_by_preconditioner(
        SketchyPreconditioner(cfg),
        api.EngineConfig(
            block_size=cfg.block_size, beta2=cfg.beta2,
            update_every=cfg.update_every,
            realloc_every=realloc_every,
            start_preconditioning_step=cfg.start_preconditioning_step,
            graft=cfg.graft, graft_eps=cfg.graft_eps, diag_eps=cfg.diag_eps,
            refresh_schedule=cfg.refresh_schedule,
            refresh_mode=cfg.refresh_mode,
            profile_annotations=cfg.profile_annotations,
            kernel_backend=cfg.kernel_backend,
            second_moment_dtype=cfg.second_moment_dtype,
            quantized_epilogue=cfg.quantized_epilogue,
            stats_reduction=cfg.stats_reduction,
            stats_axis=cfg.stats_axis,
            state_dtype=cfg.state_dtype))


def second_moment_bytes(state) -> int:
    """Covariance-tracking bytes — the paper's headline memory quantity
    (excludes grafting/momentum, as Fig. 1 does)."""
    return api.second_moment_bytes(state)
