"""Sketchy Shampoo (paper Alg. 3 + Obs. 6 EMA variant) as a small
``Preconditioner`` on the shared ``scale_by_preconditioner`` engine.

Per matrix block (paper §3.4 blocking, default 1024):
  every ``update_every`` steps (paper observes only every 10th gradient —
  the "harder setting" of §6):
      (rho_L, L-sketch) <- FD-update(beta2 * L-sketch, G G^T)
      (rho_R, R-sketch) <- FD-update(beta2 * R-sketch, G^T G)
  every step:
      P = (L-sketch + (rho_L+eps) I)^{-1/4}  G  (R-sketch + (rho_R+eps) I)^{-1/4}
computed entirely in factored (U, s, rho) form — the d x d preconditioner is
never materialized and the second-moment state is O((m+n) * ell) per block
instead of O(m^2 + n^2) (Shampoo) or O(mn) (Adam).

Blocking, the diagonal (RMSProp) path for vectors/scalars, grafting (paper
App. C: RMSPROP_NORMALIZED), and the ``update_every`` /
``start_preconditioning_step`` gating all live in the engine (core/api.py);
this module only supplies the FD sketch pair.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, ClassVar, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import api, blocking
from repro.core.fd import FDState, fd_apply_inverse_root, fd_init, fd_update
from repro.core.transform import GradientTransformation


@dataclasses.dataclass(frozen=True)
class SketchyConfig:
    rank: int = 256                 # ell; paper fixes 256 (untuned)
    block_size: int = 1024          # paper App. C
    beta2: float = 0.999            # second-moment EMA (paper §5.2)
    update_every: int = 10          # FD observes every k-th gradient (paper §6)
    start_preconditioning_step: int = 0   # paper App. C uses 101 at scale
    matrix_eps: float = 1e-6
    graft_eps: float = 1e-8
    diag_eps: Optional[float] = None      # diag-fallback damping (None => graft_eps)
    graft: str = "rmsprop_normalized"     # rmsprop_normalized | rmsprop | none
    refresh_schedule: str = "synchronized"  # synchronized | staggered
    exponent: float = -0.25         # per-side inverse root (Alg. 3)
    state_dtype: Any = jnp.float32
    use_kernels: bool = False       # route matmuls through Pallas ops


class SketchyBlockStats(NamedTuple):
    """Per-block FD sketch pair; in engine state these are batched over the
    leaf's block stack: eigvecs (S, d, ell), eigvals (S, ell), rho (S,)."""
    left: FDState
    right: FDState


def _tag_fd(st: FDState) -> FDState:
    return FDState(*(api.tag(x, "second_moment", blocked=True) for x in st))


@dataclasses.dataclass(frozen=True)
class SketchyPreconditioner:
    """FD sketch pair (paper Alg. 3) — the whole optimizer-specific surface."""
    cfg: SketchyConfig
    gram_fn: Optional[Callable] = None
    lowrank_fn: Optional[Callable] = None

    diagonal: ClassVar[bool] = False

    def init_block(self, info: blocking.BlockInfo) -> SketchyBlockStats:
        ell_l = min(self.cfg.rank, info.bs_m)
        ell_r = min(self.cfg.rank, info.bs_n)
        return SketchyBlockStats(
            left=_tag_fd(fd_init(info.bs_m, ell_l, self.cfg.state_dtype)),
            right=_tag_fd(fd_init(info.bs_n, ell_r, self.cfg.state_dtype)))

    def update_stats(self, state, G, *, count):
        return state  # FD observation is the gated refresh, not per-step

    def refresh(self, state, G, *, count):
        return SketchyBlockStats(
            left=fd_update(state.left, G, self.cfg.beta2,
                           gram_fn=self.gram_fn),
            right=fd_update(state.right, G.T, self.cfg.beta2,
                            gram_fn=self.gram_fn))

    def precondition(self, state, G, *, count):
        tmp = fd_apply_inverse_root(state.left, G,
                                    exponent=self.cfg.exponent,
                                    eps=self.cfg.matrix_eps,
                                    lowrank_fn=self.lowrank_fn)
        tmpT = fd_apply_inverse_root(state.right, tmp.T,
                                     exponent=self.cfg.exponent,
                                     eps=self.cfg.matrix_eps,
                                     lowrank_fn=self.lowrank_fn)
        return tmpT.T


def sketchy(cfg: SketchyConfig = SketchyConfig()) -> GradientTransformation:
    """S-Shampoo direction transform (emits a descent direction, no lr)."""
    gram_fn = None
    lowrank_fn = None
    if cfg.use_kernels:
        from repro.kernels.gram import ops as gram_ops
        from repro.kernels.lowrank import ops as lowrank_ops
        gram_fn = gram_ops.gram
        lowrank_fn = lowrank_ops.lowrank_apply

    return api.scale_by_preconditioner(
        SketchyPreconditioner(cfg, gram_fn=gram_fn, lowrank_fn=lowrank_fn),
        api.EngineConfig(
            block_size=cfg.block_size, beta2=cfg.beta2,
            update_every=cfg.update_every,
            start_preconditioning_step=cfg.start_preconditioning_step,
            graft=cfg.graft, graft_eps=cfg.graft_eps, diag_eps=cfg.diag_eps,
            refresh_schedule=cfg.refresh_schedule,
            state_dtype=cfg.state_dtype))


def second_moment_bytes(state) -> int:
    """Covariance-tracking bytes — the paper's headline memory quantity
    (excludes grafting/momentum, as Fig. 1 does)."""
    return api.second_moment_bytes(state)
