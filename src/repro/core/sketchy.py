"""Sketchy Shampoo (paper Alg. 3 + Obs. 6 EMA variant) as a small
``Preconditioner`` on the shared ``scale_by_preconditioner`` engine.

Per matrix block (paper §3.4 blocking, default 1024):
  every ``update_every`` steps (paper observes only every 10th gradient —
  the "harder setting" of §6):
      (rho_L, L-sketch) <- FD-update(beta2 * L-sketch, G G^T)
      (rho_R, R-sketch) <- FD-update(beta2 * R-sketch, G^T G)
  every step:
      P = (L-sketch + (rho_L+eps) I)^{-1/4}  G  (R-sketch + (rho_R+eps) I)^{-1/4}
computed entirely in factored (U, s, rho) form — the d x d preconditioner is
never materialized and the second-moment state is O((m+n) * ell) per block
instead of O(m^2 + n^2) (Shampoo) or O(mn) (Adam).

Blocking, the diagonal (RMSProp) path for vectors/scalars, grafting (paper
App. C: RMSPROP_NORMALIZED), and the ``update_every`` /
``start_preconditioning_step`` gating all live in the engine (core/api.py);
this module only supplies the FD sketch pair.  The engine injects its
resolved ``KernelSet`` (``kernel_backend`` knob: pallas | xla | auto) into
``kernels``; the ``*_batched`` methods — the pooled hot path — route the
Gram and the fused low-rank apply through the grid-over-N batched kernels,
one call per packed pool stack instead of a vmap over single-block kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, NamedTuple, Optional

import jax.numpy as jnp

from repro.core import api, blocking
from repro.core.fd import (FDState, fd_apply_inverse_root,
                           fd_apply_inverse_root_batched, fd_init, fd_update,
                           fd_update_batched)
from repro.core.transform import GradientTransformation
from repro.kernels.registry import KernelSet


@dataclasses.dataclass(frozen=True)
class SketchyConfig:
    rank: int = 256                 # ell; paper fixes 256 (untuned)
    block_size: int = 1024          # paper App. C
    beta2: float = 0.999            # second-moment EMA (paper §5.2)
    update_every: int = 10          # FD observes every k-th gradient (paper §6)
    start_preconditioning_step: int = 0   # paper App. C uses 101 at scale
    matrix_eps: float = 1e-6
    graft_eps: float = 1e-8
    diag_eps: Optional[float] = None      # diag-fallback damping (None => graft_eps)
    graft: str = "rmsprop_normalized"     # rmsprop_normalized | rmsprop | none
    refresh_schedule: str = "synchronized"  # synchronized | staggered
    # "inline" (parity default) | "async": launch the FD refresh at step t
    # from the just-updated stats, commit it at t+1 — the eigh and the
    # butterfly merge rounds leave the update direction's critical path
    # (engine refresh pipeline, core/api.py)
    refresh_mode: str = "inline"
    # profiling spans around the engine phases (core/api.py _span)
    profile_annotations: bool = False
    exponent: float = -0.25         # per-side inverse root (Alg. 3)
    state_dtype: Any = jnp.float32
    # kernel backend for the pooled hot path (engine-resolved KernelSet):
    # "pallas" | "xla" | "auto" — replaces the old private use_kernels flag
    kernel_backend: str = "auto"
    # storage dtype for the pooled FD sketches between steps
    # (core/quantize.py): "fp32" (bitwise parity) | "bf16" | "int8"
    second_moment_dtype: str = "fp32"
    # second-moment maintenance across data-parallel shards
    # (src/repro/distributed/): "replicated" (parity default) | "sharded"
    # (local FD updates + log-depth butterfly sketch merge over stats_axis
    # at refresh time)
    stats_reduction: str = "replicated"
    stats_axis: str = "data"
    # exchange precision for the merge wire (sketch_merge.pack_wire):
    # "int8" (default, ~(ell-1)*d int8 per block per round) | "fp32"
    # (exact merge — the FD error bound holds with no quantization slack)
    stats_wire_dtype: str = "int8"
    # fused int8 compute (core/api.py): "auto" (on when second_moment_dtype
    # is int8, the pallas backend is resolved, and stats are replicated) |
    # "off" (always dequantize at the boundary) | "on" (force; any backend)
    quantized_epilogue: str = "auto"


class SketchyBlockStats(NamedTuple):
    """Per-block FD sketch pair; in engine state these are batched over the
    leaf's block stack: eigvecs (S, d, ell), eigvals (S, ell), rho (S,)."""
    left: FDState
    right: FDState


def _tag_fd(st: FDState) -> FDState:
    return FDState(*(api.tag(x, "second_moment", blocked=True) for x in st))


@dataclasses.dataclass(frozen=True)
class SketchyPreconditioner:
    """FD sketch pair (paper Alg. 3) — the whole optimizer-specific surface.

    ``kernels`` is injected by the engine (``EngineConfig.kernel_backend``);
    ``None`` means plain jnp.  The batched methods run once per packed
    ``(N, bs_m, bs_n)`` pool stack.
    """
    cfg: SketchyConfig
    kernels: Optional[KernelSet] = None

    diagonal: ClassVar[bool] = False
    # the batched FD methods dispatch on QuantizedPool eigvec stacks
    # (core/fd.py), so the engine's fused int8 mode can hand this
    # preconditioner the storage containers directly
    supports_quantized_compute: ClassVar[bool] = True

    def init_block(self, info: blocking.BlockInfo) -> SketchyBlockStats:
        ell_l = min(self.cfg.rank, info.bs_m)
        ell_r = min(self.cfg.rank, info.bs_n)
        return SketchyBlockStats(
            left=_tag_fd(fd_init(info.bs_m, ell_l, self.cfg.state_dtype)),
            right=_tag_fd(fd_init(info.bs_n, ell_r, self.cfg.state_dtype)))

    # ------------------------------------------------- per-block (reference)

    def update_stats(self, state, G, *, count):
        return state  # FD observation is the gated refresh, not per-step

    def refresh(self, state, G, *, count):
        return SketchyBlockStats(
            left=fd_update(state.left, G, self.cfg.beta2,
                           kernels=self.kernels),
            right=fd_update(state.right, G.T, self.cfg.beta2,
                            kernels=self.kernels))

    def precondition(self, state, G, *, count):
        tmp = fd_apply_inverse_root(state.left, G,
                                    exponent=self.cfg.exponent,
                                    eps=self.cfg.matrix_eps,
                                    kernels=self.kernels)
        tmpT = fd_apply_inverse_root(state.right, tmp.T,
                                     exponent=self.cfg.exponent,
                                     eps=self.cfg.matrix_eps,
                                     kernels=self.kernels)
        return tmpT.T

    # ------------------------------------------- pooled-stack (kernel path)

    def update_stats_batched(self, state, G, *, count):
        return state

    def refresh_batched(self, state, G, *, count):
        return SketchyBlockStats(
            left=fd_update_batched(state.left, G, self.cfg.beta2,
                                   kernels=self.kernels),
            right=fd_update_batched(state.right, jnp.swapaxes(G, -1, -2),
                                    self.cfg.beta2, kernels=self.kernels))

    def refresh_sharded_batched(self, state, G, *, count, axis, axis_size):
        """Sharded-statistics refresh (engine ``stats_reduction="sharded"``):
        FD-update both sketch stacks on this shard's LOCAL gradient stack,
        then butterfly-merge each across the data axis so every shard ends
        the refresh holding the identical combined sketch.  Must run inside
        ``shard_map`` with ``axis`` bound (the engine guarantees it).

        The incoming sketch is replicated over the axis (the previous merge
        left it so), and the butterfly *sums* covariances — so the carried
        state is pre-scaled by 1/P to enter the merged total exactly once:
        merged ~= beta2 * S_prev + (1/P) sum_i G_i G_i^T (the engine already
        scaled the local gradient stack by 1/sqrt(P)), which coincides with
        the replicated ``beta2 * S_prev + Gbar Gbar^T`` when shards agree.
        """
        from repro.distributed import reduce as dreduce
        inv = 1.0 / axis_size
        scale = lambda fd: FDState(eigvecs=fd.eigvecs,
                                   eigvals=fd.eigvals * inv,
                                   rho=fd.rho * inv)
        state = SketchyBlockStats(left=scale(state.left),
                                  right=scale(state.right))
        local = self.refresh_batched(state, G, count=count)
        merge = lambda st: dreduce.butterfly_merge_fd(
            st, axis=axis, axis_size=axis_size, kernels=self.kernels,
            wire_dtype=self.cfg.stats_wire_dtype)
        return SketchyBlockStats(left=merge(local.left),
                                 right=merge(local.right))

    def precondition_batched(self, state, G, *, count):
        tmp = fd_apply_inverse_root_batched(
            state.left, G, exponent=self.cfg.exponent,
            eps=self.cfg.matrix_eps, kernels=self.kernels)
        tmpT = fd_apply_inverse_root_batched(
            state.right, jnp.swapaxes(tmp, -1, -2),
            exponent=self.cfg.exponent, eps=self.cfg.matrix_eps,
            kernels=self.kernels)
        return jnp.swapaxes(tmpT, -1, -2)


def sketchy(cfg: SketchyConfig = SketchyConfig()) -> GradientTransformation:
    """S-Shampoo direction transform (emits a descent direction, no lr)."""
    return api.scale_by_preconditioner(
        SketchyPreconditioner(cfg),
        api.EngineConfig(
            block_size=cfg.block_size, beta2=cfg.beta2,
            update_every=cfg.update_every,
            start_preconditioning_step=cfg.start_preconditioning_step,
            graft=cfg.graft, graft_eps=cfg.graft_eps, diag_eps=cfg.diag_eps,
            refresh_schedule=cfg.refresh_schedule,
            refresh_mode=cfg.refresh_mode,
            profile_annotations=cfg.profile_annotations,
            kernel_backend=cfg.kernel_backend,
            second_moment_dtype=cfg.second_moment_dtype,
            quantized_epilogue=cfg.quantized_epilogue,
            stats_reduction=cfg.stats_reduction,
            stats_axis=cfg.stats_axis,
            state_dtype=cfg.state_dtype))


def second_moment_bytes(state) -> int:
    """Covariance-tracking bytes — the paper's headline memory quantity
    (excludes grafting/momentum, as Fig. 1 does)."""
    return api.second_moment_bytes(state)
