"""Sketchy Shampoo (paper Alg. 3 + Obs. 6 EMA variant) as a composable
GradientTransformation.

Per matrix block (paper §3.4 blocking, default 1024):
  every ``update_every`` steps (paper observes only every 10th gradient —
  the "harder setting" of §6):
      (rho_L, L-sketch) <- FD-update(beta2 * L-sketch, G G^T)
      (rho_R, R-sketch) <- FD-update(beta2 * R-sketch, G^T G)
  every step:
      P = (L-sketch + (rho_L+eps) I)^{-1/4}  G  (R-sketch + (rho_R+eps) I)^{-1/4}
computed entirely in factored (U, s, rho) form — the d x d preconditioner is
never materialized and the second-moment state is O((m+n) * ell) per block
instead of O(m^2 + n^2) (Shampoo) or O(mn) (Adam).

Vectors/scalars take the diagonal (RMSProp) path, as Shampoo itself does.
Grafting (paper App. C: RMSPROP_NORMALIZED) supplies the per-tensor step size.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import blocking
from repro.core.fd import FDState, fd_apply_inverse_root, fd_init, fd_update
from repro.core.transform import GradientTransformation


@dataclasses.dataclass(frozen=True)
class SketchyConfig:
    rank: int = 256                 # ell; paper fixes 256 (untuned)
    block_size: int = 1024          # paper App. C
    beta2: float = 0.999            # second-moment EMA (paper §5.2)
    update_every: int = 10          # FD observes every k-th gradient (paper §6)
    start_preconditioning_step: int = 0   # paper App. C uses 101 at scale
    matrix_eps: float = 1e-6
    graft_eps: float = 1e-8
    graft: str = "rmsprop_normalized"     # rmsprop_normalized | rmsprop | none
    exponent: float = -0.25         # per-side inverse root (Alg. 3)
    state_dtype: Any = jnp.float32
    use_kernels: bool = False       # route matmuls through Pallas ops


class MatrixLeafState(NamedTuple):
    left: FDState     # batched over blocks: (S, bm, ell), (S, ell), (S,)
    right: FDState
    graft_acc: jnp.ndarray


class DiagLeafState(NamedTuple):
    acc: jnp.ndarray


class SketchyState(NamedTuple):
    count: jnp.ndarray
    leaves: tuple


def _graft_direction(g, acc, cfg: SketchyConfig):
    """Returns (graft_direction, new_acc). g, acc float32."""
    if cfg.graft == "none":
        return g, acc
    if cfg.graft == "rmsprop_normalized":
        gn = g / (jnp.linalg.norm(g) + 1e-16)
    else:
        gn = g
    acc = cfg.beta2 * acc + (1.0 - cfg.beta2) * jnp.square(gn)
    return gn * jax.lax.rsqrt(acc + cfg.graft_eps), acc


def _vmapped_fd_update(states: FDState, factors: jnp.ndarray, beta2: float,
                       gram_fn=None) -> FDState:
    return jax.vmap(lambda s, a: fd_update(s, a, beta2, gram_fn=gram_fn))(states, factors)


def _precondition_blocks(left: FDState, right: FDState, gb: jnp.ndarray,
                         cfg: SketchyConfig, lowrank_fn=None) -> jnp.ndarray:
    """P = L^{-1/4} G R^{-1/4} per block, factored form."""
    def one(ls, rs, G):
        tmp = fd_apply_inverse_root(ls, G, exponent=cfg.exponent,
                                    eps=cfg.matrix_eps, lowrank_fn=lowrank_fn)
        tmpT = fd_apply_inverse_root(rs, tmp.T, exponent=cfg.exponent,
                                     eps=cfg.matrix_eps, lowrank_fn=lowrank_fn)
        return tmpT.T

    return jax.vmap(one)(left, right, gb)


def sketchy(cfg: SketchyConfig = SketchyConfig()) -> GradientTransformation:
    """S-Shampoo direction transform (emits a descent direction, no lr)."""
    gram_fn = None
    lowrank_fn = None
    if cfg.use_kernels:
        from repro.kernels.gram import ops as gram_ops
        from repro.kernels.lowrank import ops as lowrank_ops
        gram_fn = gram_ops.gram
        lowrank_fn = lowrank_ops.lowrank_apply

    def init_leaf(p):
        info = blocking.analyze(p.shape, cfg.block_size)
        if info.kind == "diag":
            return DiagLeafState(acc=jnp.zeros(p.shape, cfg.state_dtype))
        S = info.num_blocks
        ell_l = min(cfg.rank, info.bs_m)
        ell_r = min(cfg.rank, info.bs_n)

        def batched_fd(d, ell):
            base = fd_init(d, ell, cfg.state_dtype)
            return FDState(*[jnp.broadcast_to(x, (S,) + x.shape) for x in base])

        return MatrixLeafState(
            left=batched_fd(info.bs_m, ell_l),
            right=batched_fd(info.bs_n, ell_r),
            graft_acc=jnp.zeros(p.shape, cfg.state_dtype),
        )

    def init_fn(params):
        leaves = tuple(init_leaf(p) for p in jax.tree.leaves(params))
        return SketchyState(count=jnp.zeros([], jnp.int32), leaves=leaves)

    def update_leaf(g, st, count):
        g32 = g.astype(jnp.float32)
        info = blocking.analyze(g.shape, cfg.block_size)
        if info.kind == "diag":
            acc = cfg.beta2 * st.acc + (1.0 - cfg.beta2) * jnp.square(g32)
            direction = g32 * jax.lax.rsqrt(acc + cfg.graft_eps)
            return direction.astype(g.dtype), DiagLeafState(acc=acc)

        gb = blocking.to_blocks(g32, info)  # (S, bm, bn)
        gbT = jnp.swapaxes(gb, -1, -2)

        do_stats = (count % cfg.update_every) == 0

        def with_stats(s):
            return MatrixLeafState(
                left=_vmapped_fd_update(s.left, gb, cfg.beta2, gram_fn),
                right=_vmapped_fd_update(s.right, gbT, cfg.beta2, gram_fn),
                graft_acc=s.graft_acc,
            )

        st = jax.lax.cond(do_stats, with_stats, lambda s: s, st)

        pb = _precondition_blocks(st.left, st.right, gb, cfg, lowrank_fn)
        precond = blocking.from_blocks(pb, info)

        graft_dir, new_acc = _graft_direction(g32, st.graft_acc, cfg)
        if cfg.graft != "none":
            pnorm = jnp.linalg.norm(precond)
            gnorm = jnp.linalg.norm(graft_dir)
            precond = precond * (gnorm / (pnorm + 1e-16))

        use_precond = count >= cfg.start_preconditioning_step
        direction = jnp.where(use_precond, precond, graft_dir)
        return direction.astype(g.dtype), MatrixLeafState(st.left, st.right, new_acc)

    def update_fn(updates, state, params=None):
        del params
        flat, treedef = jax.tree.flatten(updates)
        out_flat, new_leaves = [], []
        for g, st in zip(flat, state.leaves):
            d, ns = update_leaf(g, st, state.count)
            out_flat.append(d)
            new_leaves.append(ns)
        return (jax.tree.unflatten(treedef, out_flat),
                SketchyState(count=state.count + 1, leaves=tuple(new_leaves)))

    return GradientTransformation(init_fn, update_fn)


def second_moment_bytes(state: SketchyState) -> int:
    """Bytes used for second-moment (covariance) tracking — the paper's
    headline memory quantity (excludes grafting/momentum, as Fig. 1 does)."""
    total = 0
    for leaf in state.leaves:
        if isinstance(leaf, MatrixLeafState):
            for fs in (leaf.left, leaf.right):
                total += fs.eigvecs.size * fs.eigvecs.dtype.itemsize
                total += fs.eigvals.size * fs.eigvals.dtype.itemsize
                total += fs.rho.size * fs.rho.dtype.itemsize
        else:
            total += leaf.acc.size * leaf.acc.dtype.itemsize
    return total
