"""Minimal optax-style gradient-transformation infrastructure.

optax is not available offline; this module provides the same composable
(init_fn, update_fn) contract so the rest of the framework can treat
optimizers as pure pytree->pytree functions (jit/pjit friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

PyTree = Any


class GradientTransformation(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], tuple[PyTree, PyTree]]


class EmptyState(NamedTuple):
    pass


def identity() -> GradientTransformation:
    def init_fn(params):
        del params
        return EmptyState()

    def update_fn(updates, state, params=None):
        del params
        return updates, state

    return GradientTransformation(init_fn, update_fn)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transformations; state is a tuple of member states."""

    def init_fn(params):
        return tuple(t.init(params) for t in transforms)

    def update_fn(updates, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            updates, s = t.update(updates, s, params)
            new_state.append(s)
        return updates, tuple(new_state)

    return GradientTransformation(init_fn, update_fn)


class ScaleByScheduleState(NamedTuple):
    count: jnp.ndarray


def scale(factor: float) -> GradientTransformation:
    def init_fn(params):
        del params
        return EmptyState()

    def update_fn(updates, state, params=None):
        del params
        return jax.tree.map(lambda u: u * factor, updates), state

    return GradientTransformation(init_fn, update_fn)


def scale_by_schedule(schedule: Callable[[jnp.ndarray], jnp.ndarray]) -> GradientTransformation:
    def init_fn(params):
        del params
        return ScaleByScheduleState(count=jnp.zeros([], jnp.int32))

    def update_fn(updates, state, params=None):
        del params
        step_size = schedule(state.count)
        updates = jax.tree.map(lambda u: u * step_size, updates)
        return updates, ScaleByScheduleState(count=state.count + 1)

    return GradientTransformation(init_fn, update_fn)


class TraceState(NamedTuple):
    momentum: PyTree


def momentum(beta1: float, *, ema: bool = True, dtype=None) -> GradientTransformation:
    """Heavy-ball / EMA momentum.

    ema=True matches the paper's ``moving_average_for_momentum``:
    final update is ``beta1 * mu_t + (1 - beta1) * g_t``.

    State leaves carry ``StateMeta(role='momentum', param_index=i)`` so
    sharding/checkpoint/memory consumers handle them by metadata.
    """
    from repro.core import api  # deferred: api imports this module

    def init_fn(params):
        flat, treedef = jax.tree.flatten(params)
        mom = [api.tag(jnp.zeros_like(p, dtype=dtype or p.dtype),
                       "momentum", param_index=i)
               for i, p in enumerate(flat)]
        return TraceState(momentum=jax.tree.unflatten(treedef, mom))

    def update_fn(updates, state, params=None):
        del params
        # map over (updates, momentum): updates' leaf positions align with
        # the Tagged nodes, so each fn call sees (grad leaf, Tagged).
        if ema:
            mu = jax.tree.map(
                lambda u, t: api.Tagged(
                    beta1 * t.value + (1.0 - beta1) * u.astype(t.value.dtype),
                    t.meta),
                updates, state.momentum,
            )
        else:
            mu = jax.tree.map(
                lambda u, t: api.Tagged(beta1 * t.value + u.astype(t.value.dtype),
                                        t.meta),
                updates, state.momentum,
            )
        out = jax.tree.map(lambda u, t: t.value.astype(u.dtype), updates, mu)
        return out, TraceState(momentum=mu)

    return GradientTransformation(init_fn, update_fn)


def add_decayed_weights(weight_decay: float) -> GradientTransformation:
    """Decoupled weight decay (paper uses decoupled AdamW-style decay)."""

    def init_fn(params):
        del params
        return EmptyState()

    def update_fn(updates, state, params=None):
        if weight_decay == 0.0 or params is None:
            return updates, state
        updates = jax.tree.map(
            lambda u, p: u + weight_decay * p.astype(u.dtype), updates, params
        )
        return updates, state

    return GradientTransformation(init_fn, update_fn)


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init_fn(params):
        del params
        return EmptyState()

    def update_fn(updates, state, params=None):
        del params
        leaves = jax.tree.leaves(updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
        scale_f = jnp.minimum(1.0, max_norm / (gnorm + 1e-16))
        updates = jax.tree.map(lambda u: (u * scale_f).astype(u.dtype), updates)
        return updates, state

    return GradientTransformation(init_fn, update_fn)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """params + updates (updates already carry the negative learning rate)."""
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)).astype(p.dtype), params, updates)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """Bundles a transformation with the convention update = -lr * direction."""

    tx: GradientTransformation

    def init(self, params):
        return self.tx.init(params)

    def update(self, grads, state, params):
        return self.tx.update(grads, state, params)
