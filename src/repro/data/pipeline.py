"""Deterministic synthetic token pipeline with per-host sharding.

Production shape: each host generates only its shard of the global batch
(indexed by (step, host_id) so restarts are exactly reproducible — the
checkpoint stores just the step cursor). The LM stream is a mixture of
Zipf-distributed unigrams and deterministic repeated motifs so models have
actual structure to learn in the e2e examples.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 16
    num_codebooks: int = 0      # musicgen-style multi-stream tokens
    embed_dim: int = 0          # >0: emit embeddings (vlm frontend stub)


class SyntheticLM:
    """Stateless batch generator: batch(step, host, num_hosts)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed motif bank shared by all hosts
        self.motifs = rng.integers(
            0, cfg.vocab_size, size=(64, cfg.motif_len), dtype=np.int64)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self.unigram = p / p.sum()

    def _tokens(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        cfg = self.cfg
        S = cfg.seq_len + 1
        toks = rng.choice(cfg.vocab_size, size=(batch, S), p=self.unigram)
        # plant motifs: second half of a motif is predictable from the first
        if S > cfg.motif_len:
            n_plants = max(S // (4 * cfg.motif_len), 1)
            for b in range(batch):
                for _ in range(n_plants):
                    m = self.motifs[rng.integers(0, len(self.motifs))]
                    start = rng.integers(0, S - cfg.motif_len)
                    toks[b, start:start + cfg.motif_len] = m
        return toks.astype(np.int32)

    def batch(self, step: int, host: int = 0, num_hosts: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        local = cfg.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host]))
        out = {}
        if cfg.num_codebooks:
            streams = [self._tokens(rng, local) for _ in range(cfg.num_codebooks)]
            toks = np.stack(streams, axis=-1)       # (B, S+1, K)
            out["tokens"] = toks[:, :-1]
            out["labels"] = toks[:, 1:]
        elif cfg.embed_dim:
            toks = self._tokens(rng, local)
            table = np.random.default_rng(cfg.seed).normal(
                size=(cfg.vocab_size, cfg.embed_dim)).astype(np.float32) * 0.02
            out["embeds"] = table[toks[:, :-1]]
            out["labels"] = toks[:, 1:]
        else:
            toks = self._tokens(rng, local)
            out["tokens"] = toks[:, :-1]
            out["labels"] = toks[:, 1:]
        return out

    def iter_batches(self, start_step: int = 0, host: int = 0,
                     num_hosts: int = 1) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step, host, num_hosts)
            step += 1


def device_put_batch(batch: dict, shardings: Optional[dict] = None) -> dict:
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(jnp.asarray(v), shardings[k])
            for k, v in batch.items()}
