"""Distributed FD: sharded second-moment statistics via mergeable sketches.

The paper's premise is that the gradient covariance lives in a small leading
eigenspace — yet replicated data-parallel training still all-reduces full
dense gradients and has every replica redundantly maintain identical
sketches.  FD sketches are *mergeable* (concatenate weighted factors,
re-shrink to rank ell) and Robust FD shows the escaped mass ``rho`` survives
such combinations, so the second moment can instead be maintained as:

  1. each data-parallel shard FD-updates its pooled sketch stacks on its
     *local* microbatch gradients (``core/fd.fd_update_batched``), and
  2. at refresh time a log-depth butterfly merge over the ``data`` mesh axis
     (``reduce.butterfly_merge_fd``: ``jax.lax.ppermute`` rounds inside the
     ``sharding/rules.shard_map`` wrapper) combines the ``(N, d, ell)``
     stacks via ``core/fd.fd_merge_batched``.

Exchanged factors ride the shared int8 rounding core (``core/quantize.py`` /
``train/compression.py``): the wire format is ``~ell * d`` int8 per block
(``sketch_merge.pack_wire``) instead of ``d^2`` fp32 gradients.

Enabled by ``stats_reduction="sharded"`` (``core/api.EngineConfig``,
threaded through ``SketchyConfig`` / ``OptimizerConfig`` /
``launch/train.py``); with no bound data axis — or a 1-sized one — the
engine takes the replicated path, bitwise-identical to the default.
"""
from repro.distributed.reduce import (bound_axis_size, butterfly_merge_fd,
                                      current_local_gradients,
                                      local_gradients, pmean)
from repro.distributed.sketch_merge import (WireSketch, merge_stack_states,
                                            merge_wire, pack_wire,
                                            unpack_wire, wire_bytes)

__all__ = [
    "bound_axis_size", "butterfly_merge_fd", "current_local_gradients",
    "local_gradients", "pmean", "WireSketch", "merge_stack_states",
    "merge_wire", "pack_wire", "unpack_wire", "wire_bytes",
]
