"""Collectives for the sharded-statistics engine path.

``butterfly_merge_fd`` is the log-depth mergeable-sketch reduction: ``P``
shards each holding a locally-updated pooled sketch stack converge to one
(identical) merged stack in ``log2(P)`` ``jax.lax.ppermute`` rounds — the
classic recursive-doubling butterfly, with ``fd_merge`` as the combiner
instead of ``+``.  Each round every shard packs its current stack into the
int8 wire form (``sketch_merge.pack_wire``), swaps it with its XOR partner,
and merges the pair in axis-index order; because the wire rounding is
deterministic and applied to both sides, all shards of a pair compute the
same merged state, so after the last round the stack is replicated across
the axis (which is exactly what the engine's out-specs assume).  Non
power-of-two axis sizes fall back to one all-gather + a single stacked
shrink (same wire bytes per shard, one wide eigh instead of log rounds).

``bound_axis_size`` detects at trace time whether a mesh axis name is bound
(we are inside ``shard_map``/``pmap``) — the engine uses it to fall back to
the replicated path bitwise when there is no data axis to reduce over.

``local_gradients`` is the trace-time side channel the trainer uses to hand
the engine per-shard *local* gradients while the update chain itself (clip,
grafting, momentum) consumes the dp-mean gradients.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.fd import FDState, fd_merge_factors_batched
from repro.distributed import sketch_merge

PyTree = Any

_local = threading.local()


def bound_axis_size(axis: str) -> Optional[int]:
    """Size of a bound mesh axis, or None when the name is unbound.

    Inside ``shard_map``/``pmap`` the counting ``psum(1, axis)`` folds to a
    static Python int at trace time (jax <= 0.4.x has no
    ``jax.lax.axis_size``); outside, the unbound name raises ``NameError``.
    """
    try:
        return int(jax.lax.psum(1, axis))
    except NameError:
        return None


def pmean(x: PyTree, axis: str) -> PyTree:
    """Mean over a bound mesh axis (pytree-polymorphic)."""
    return jax.tree.map(lambda v: v / jax.lax.psum(1, axis),
                        jax.lax.psum(x, axis))


@contextlib.contextmanager
def local_gradients(grads: PyTree):
    """Expose per-shard local gradients to the engine for the duration of a
    traced update call.  ``scale_by_preconditioner`` reads them via
    ``current_local_gradients`` on its sharded-stats path; the gradients
    flowing through the transformation chain stay the dp-mean ones, so
    clipping/grafting/momentum are unchanged."""
    prev = getattr(_local, "grads", None)
    _local.grads = grads
    try:
        yield
    finally:
        _local.grads = prev


def current_local_gradients() -> Optional[PyTree]:
    return getattr(_local, "grads", None)


def _gather_shrink(state: FDState, *, axis: str, axis_size: int, ell: int,
                   kernels, wire_dtype: str) -> FDState:
    """all-gather fallback for non-power-of-two axes: one exchange, one wide
    stacked shrink over all P factors."""
    wire = sketch_merge.pack_wire(state, wire_dtype)
    gathered = jax.lax.all_gather(wire, axis)        # leaves gain leading P
    B = gathered.values.astype(jnp.float32) * gathered.scale
    # (P, N, d, r) -> (N, d, P*r)
    P_, N, d, r = B.shape
    M = jnp.transpose(B, (1, 2, 0, 3)).reshape(N, d, P_ * r)
    rho = jnp.sum(gathered.rho, axis=0)
    empty = jnp.zeros((N, d, 0), jnp.float32)
    return fd_merge_factors_batched(M, rho, empty, jnp.zeros_like(rho),
                                    ell=ell, kernels=kernels)


def butterfly_merge_fd(state: FDState, *, axis: str, axis_size: int,
                       kernels=None, wire_dtype: str = "int8") -> FDState:
    """Merge one pooled sketch stack across a bound mesh axis.

    Args:
      state: pooled FD stack (eigvecs ``(N, d, ell)``) holding this shard's
        locally-updated sketch; must be called inside ``shard_map`` with
        ``axis`` bound.
      axis: mesh axis name to reduce over.
      axis_size: static size of that axis (``bound_axis_size``).
      kernels: optional ``KernelSet`` for the merge Grams.
      wire_dtype: ``"int8"`` (default, ~4x fewer wire bytes) or ``"fp32"``
        (exact exchange — the FD merge error bound holds with no
        quantization slack; used by the property tests).

    Returns:
      The merged stack, identical on every shard of the axis.
    """
    if axis_size <= 1:
        return state
    ell = state.eigvecs.shape[-1]
    if axis_size & (axis_size - 1):
        with jax.named_scope("butterfly_merge_fd/gather_shrink"):
            merged = _gather_shrink(state, axis=axis, axis_size=axis_size,
                                    ell=ell, kernels=kernels,
                                    wire_dtype=wire_dtype)
    else:
        idx = jax.lax.axis_index(axis)
        merged = state
        dist = 1
        while dist < axis_size:
            with jax.named_scope(f"butterfly_merge_fd/round_d{dist}"):
                wire = sketch_merge.pack_wire(merged, wire_dtype)
                perm = [(i, i ^ dist) for i in range(axis_size)]
                other = jax.lax.ppermute(wire, axis, perm)
                # merge in axis-index order so both partners of a pair
                # compute the bitwise-identical result (concatenation order
                # matters to the eigh)
                is_low = (idx & dist) == 0
                lo = jax.tree.map(lambda a, b: jnp.where(is_low, a, b),
                                  wire, other)
                hi = jax.tree.map(lambda a, b: jnp.where(is_low, b, a),
                                  wire, other)
                merged = sketch_merge.merge_wire(lo, hi, ell=ell,
                                                 kernels=kernels)
            dist *= 2
    return FDState(eigvecs=merged.eigvecs.astype(state.eigvecs.dtype),
                   eigvals=merged.eigvals.astype(state.eigvals.dtype),
                   rho=merged.rho.astype(state.rho.dtype))
