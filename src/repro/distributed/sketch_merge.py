"""Wire format + merge rules for exchanging FD sketch stacks between shards.

A pooled sketch stack ``FDState`` (eigvecs ``(N, d, ell)``, eigvals
``(N, ell)``, rho ``(N,)``) is exchanged as its weighted factor
``B = U diag(sqrt(s))``:

  * the deflation invariant ``s[-1] == 0`` makes B's last column identically
    zero, so only ``ell - 1`` columns go on the wire
    (``fd_weighted_factor(drop_deflated=True)``);
  * under ``wire_dtype="int8"`` the factor rides the shared symmetric-int8
    core of ``core/quantize.py`` (one fp32 absmax scale per block), so one
    exchange is ``~(ell-1) * d`` int8 + O(1) fp32 per block instead of the
    ``d^2`` fp32 of a dense gradient/stat all-reduce.

Quantization on the wire is *deterministic* (round-to-nearest, no PRNG key)
and applied to **both** sides of a merge: every shard round-trips its own
factor through the same int8 grid its partner receives, so all participants
of a butterfly round compute bitwise-identical merged states and the
optimizer state stays replicated across the data axis without extra
synchronization.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quantize
from repro.core.fd import (FDState, fd_merge_batched, fd_merge_factors_batched,
                           fd_weighted_factor)

WIRE_DTYPES = ("int8", "fp32")


class WireSketch(NamedTuple):
    """One pooled sketch stack in exchange form.

    values: (N, d, r) factor — int8 under the int8 wire, fp32 otherwise.
    scale:  (N, 1, 1) fp32 absmax scales (ones under the fp32 wire).
    rho:    (N,) fp32 escaped mass carried alongside.
    """
    values: jnp.ndarray
    scale: jnp.ndarray
    rho: jnp.ndarray


def pack_wire(state: FDState, wire_dtype: str = "int8") -> WireSketch:
    """Sketch stack -> wire form (drops the deflated zero column)."""
    if wire_dtype not in WIRE_DTYPES:
        raise ValueError(f"unknown wire_dtype {wire_dtype!r}; expected one "
                         f"of {WIRE_DTYPES}")
    B = fd_weighted_factor(state, drop_deflated=True)   # (N, d, ell-1)
    rho = state.rho.astype(jnp.float32)
    if wire_dtype == "fp32":
        ones = jnp.ones((B.shape[0],) + (1,) * (B.ndim - 1), jnp.float32)
        return WireSketch(values=B.astype(jnp.float32), scale=ones, rho=rho)
    # deterministic rounding (no key): both merge sides must land on the
    # same grid — see module docstring
    qp = quantize.quantize_stack(B)
    return WireSketch(values=qp.values, scale=qp.scale, rho=rho)


def unpack_wire(wire: WireSketch) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Wire form -> (fp32 weighted factor, rho)."""
    if wire.values.dtype == jnp.float32:
        return wire.values, wire.rho
    return quantize.dequantize_stack(wire.values, wire.scale), wire.rho


def wire_bytes(wire: WireSketch) -> int:
    """Bytes one shard puts on the wire per exchange of this stack."""
    return sum(int(x.size) * jnp.dtype(x.dtype).itemsize for x in wire)


def merge_wire(a: WireSketch, b: WireSketch, *, ell: int,
               kernels=None) -> FDState:
    """Merge two wire sketches into a rank-``ell`` stack (both sides
    dequantized through the identical int8 grid)."""
    Ba, rho_a = unpack_wire(a)
    Bb, rho_b = unpack_wire(b)
    return fd_merge_factors_batched(Ba, rho_a, Bb, rho_b, ell=ell,
                                    kernels=kernels)


def merge_stack_states(states, kernels=None) -> FDState:
    """Exact (no wire) pairwise-tree merge of a list of same-shaped pooled
    sketch stacks — the host-side hook for elastic mesh shrink
    (``train/elastic.py``): sketches of departing shards fold into the
    survivors' without restarting the statistics from zero."""
    states = list(states)
    if not states:
        raise ValueError("merge_stack_states needs at least one state")
    while len(states) > 1:
        nxt = [fd_merge_batched(states[i], states[i + 1], kernels=kernels)
               for i in range(0, len(states) - 1, 2)]
        if len(states) % 2:
            nxt.append(states[-1])
        states = nxt
    return states[0]
