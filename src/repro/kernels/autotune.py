"""Shape-aware tile autotuner for the batched Pallas kernels.

PR 3 shipped the grid-over-N batched kernels with fixed tile defaults
(``bn_stack=1``, ``bk=128``, ``bd=256``, ``bn=256``) — correct everywhere,
optimal nowhere.  This module closes the ROADMAP's "``bn_stack``/tile
tuning" item with a *measured* search: for each (platform, kernel, pool
shape, storage dtype) it times every candidate ``TileConfig`` on
synthetic operands of exactly that shape and records the winner in a
persistent JSON cache.

Resolution is cheap and happens at *trace* time: the registry's pallas
entry points call :func:`get_config` with the operand shape while the
engine's update function is being traced, so a tuned config costs zero
per-step work — the jitted step simply bakes in different static tile
arguments.

Tune modes (``REPRO_TUNE_MODE``, default ``"auto"``):

  * ``"off"``   — every lookup returns the defaults.  This is what keeps
                  the untuned path bitwise-pinned: tile sizes change the
                  f32 accumulation order, so the parity tests force
                  ``"off"`` (or simply never commit entries for their
                  shapes).
  * ``"auto"``  — cache hit wins, miss falls back to the defaults.  No
                  measurement ever runs implicitly; CI stays
                  deterministic against the committed fixture.
  * ``"force"`` — cache miss triggers an in-process measured search and
                  the winner is persisted.  Intended for offline cache
                  generation (the ``python -m repro.kernels.autotune``
                  CLI, benchmarks); avoid inside traced code paths.

The cache file defaults to the committed fixture next to this module
(``tune_cache.json`` — CI validates it against the candidate-space
schema); ``REPRO_TUNE_CACHE`` points lookups at a different path.
"""
from __future__ import annotations

import argparse
import functools
import itertools
import json
import os
import time
from typing import Any, NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

TUNE_MODES = ("auto", "off", "force")
ENV_CACHE = "REPRO_TUNE_CACHE"
ENV_MODE = "REPRO_TUNE_MODE"
DEFAULT_CACHE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                  "tune_cache.json")
CACHE_VERSION = 1


class TileConfig(NamedTuple):
    """Static tile arguments of the batched kernels.

    ``bn_stack`` (pool blocks per grid step) applies to every batched
    kernel; ``bk``/``bd`` tile the Gram contraction; ``bn`` tiles the
    low-rank apply's output columns.  Fields a kernel does not use are
    pinned to the defaults so equivalent configs dedupe/intern cleanly.
    """
    bn_stack: int = 1
    bk: int = 128
    bd: int = 256
    bn: int = 256


DEFAULT_CONFIG = TileConfig()

# kernel name -> which TileConfig fields it actually consumes
KERNELS = ("batched_gram", "batched_gram_mixed", "batched_lowrank_apply",
           "batched_project_quantize")

_BN_STACK = (1, 2, 4, 8)
_BK = (64, 128, 256)
_BD = (128, 256, 512)
_BN = (128, 256, 512)


# --------------------------------------------------------------- candidates


def effective(kernel: str, shape: tuple, config: TileConfig) -> TileConfig:
    """Clamp a candidate to the shape exactly like the kernel will, and pin
    unused fields to the defaults — so candidates that would compile the
    same grid compare equal and dedupe."""
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; expected one of "
                         f"{KERNELS}")
    N = shape[0]
    bn_stack = min(config.bn_stack, max(N, 1))
    if kernel == "batched_gram":          # shape (N, d, k)
        _, d, k = shape
        return TileConfig(bn_stack=bn_stack, bk=min(config.bk, max(k, 1)),
                          bd=min(config.bd, max(d, 1)), bn=DEFAULT_CONFIG.bn)
    if kernel == "batched_gram_mixed":    # shape (N, d, k, r); d-tiled only
        d = shape[1]
        return TileConfig(bn_stack=bn_stack, bk=DEFAULT_CONFIG.bk,
                          bd=min(config.bd, max(d, 1)), bn=DEFAULT_CONFIG.bn)
    if kernel == "batched_lowrank_apply":  # shape (N, d, ell, n)
        n = shape[3]
        return TileConfig(bn_stack=bn_stack, bk=DEFAULT_CONFIG.bk,
                          bd=DEFAULT_CONFIG.bd, bn=min(config.bn, max(n, 1)))
    # batched_project_quantize: whole-block per grid step, only bn_stack
    return TileConfig(bn_stack=bn_stack, bk=DEFAULT_CONFIG.bk,
                      bd=DEFAULT_CONFIG.bd, bn=DEFAULT_CONFIG.bn)


def candidates(kernel: str, shape: tuple) -> list:
    """Deduped candidate TileConfigs for one (kernel, shape); the effective
    default config is always first (ties in the measured search keep it)."""
    menu = {"batched_gram": itertools.product(_BN_STACK, _BK, _BD),
            "batched_gram_mixed": itertools.product(_BN_STACK, _BD),
            "batched_lowrank_apply": itertools.product(_BN_STACK, _BN),
            "batched_project_quantize": itertools.product(_BN_STACK)}
    out = [effective(kernel, shape, DEFAULT_CONFIG)]
    seen = set(out)
    for combo in menu[kernel]:
        if kernel == "batched_gram":
            cand = TileConfig(bn_stack=combo[0], bk=combo[1], bd=combo[2])
        elif kernel == "batched_gram_mixed":
            cand = TileConfig(bn_stack=combo[0], bd=combo[1])
        elif kernel == "batched_lowrank_apply":
            cand = TileConfig(bn_stack=combo[0], bn=combo[1])
        else:
            cand = TileConfig(bn_stack=combo[0])
        eff = effective(kernel, shape, cand)
        if eff not in seen:
            seen.add(eff)
            out.append(eff)
    return out


# -------------------------------------------------------------- cache state


@functools.lru_cache(maxsize=None)
def platform() -> str:
    """Cache key component; probed once per process like registry.on_tpu."""
    return jax.default_backend()


def _interpret() -> bool:
    return platform() != "tpu"


def key_for(kernel: str, shape: tuple, dtype) -> str:
    dims = "x".join(str(int(s)) for s in shape)
    return f"{platform()}|{kernel}|{dims}|{jnp.dtype(dtype).name}"


def parse_key(key: str) -> tuple:
    """``plat|kernel|NxDx...|dtype`` -> (platform, kernel, shape, dtype)."""
    parts = key.split("|")
    if len(parts) != 4:
        raise ValueError(f"malformed tune-cache key {key!r}")
    plat, kernel, dims, dtype = parts
    shape = tuple(int(s) for s in dims.split("x"))
    return plat, kernel, shape, dtype


_STATE: dict = {"path": None, "mode": None, "entries": None, "epoch": 0}


def _load_entries(path: str) -> dict:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        data = json.load(f)
    problems = validate_cache(data)
    if problems:
        raise ValueError(f"invalid tune cache {path}: {problems[0]}"
                         + (f" (+{len(problems) - 1} more)"
                            if len(problems) > 1 else ""))
    return {k: TileConfig(bn_stack=v["bn_stack"], bk=v["bk"], bd=v["bd"],
                          bn=v["bn"])
            for k, v in data.get("entries", {}).items()}


def _resolve() -> dict:
    """Resolve the cache path/mode from the environment once per process
    (until an explicit ``reload``)."""
    if _STATE["entries"] is None:
        path = os.environ.get(ENV_CACHE) or DEFAULT_CACHE_PATH
        mode = os.environ.get(ENV_MODE) or "auto"
        if mode not in TUNE_MODES:
            raise ValueError(f"{ENV_MODE}={mode!r}; expected one of "
                             f"{TUNE_MODES}")
        _STATE.update(path=path, mode=mode, entries=_load_entries(path))
    return _STATE


def reload(path: Optional[str] = None, mode: Optional[str] = None) -> None:
    """Re-read the cache (optionally from a new path / with a new mode) and
    bump the resolution epoch — the registry re-interns its KernelSets
    against the new snapshot on the next ``get_kernels`` call."""
    cur = _resolve()
    path = path if path is not None else cur["path"]
    mode = mode if mode is not None else cur["mode"]
    if mode not in TUNE_MODES:
        raise ValueError(f"tune mode {mode!r}; expected one of {TUNE_MODES}")
    _STATE.update(path=path, mode=mode, entries=_load_entries(path),
                  epoch=cur["epoch"] + 1)


def cache_path() -> str:
    return _resolve()["path"]


def mode() -> str:
    return _resolve()["mode"]


def snapshot() -> tuple:
    """Hashable frozen view of the resolved entries (sorted key/config
    pairs) — the interning key for ``registry.get_kernels`` and the
    ``KernelSet.tuned`` field, so two processes resolving the same cache
    file produce equal KernelSets."""
    st = _resolve()
    return (st["mode"],) + tuple(sorted(st["entries"].items()))


def get_config(kernel: str, shape: tuple, dtype) -> TileConfig:
    """The TileConfig a kernel should run with for one operand shape.

    Called by the registry's pallas entry points at trace time; never
    measures except in ``"force"`` mode on a cache miss.
    """
    st = _resolve()
    default = effective(kernel, shape, DEFAULT_CONFIG)
    if st["mode"] == "off":
        return default
    hit = st["entries"].get(key_for(kernel, shape, dtype))
    if hit is not None:
        return effective(kernel, shape, hit)
    if st["mode"] != "force":
        return default
    best, _ = tune(kernel, shape, dtype)
    st["entries"][key_for(kernel, shape, dtype)] = best
    try:
        save(st["path"])
    except OSError:
        pass  # read-only cache location: keep the in-process entry only
    return best


# ------------------------------------------------------------ measured search


def _operands(kernel: str, shape: tuple, dtype) -> tuple:
    """Deterministic synthetic operands of exactly the tuned shape."""
    rng = np.random.default_rng(abs(hash((kernel,) + tuple(shape))) % (2**32))
    dt = jnp.dtype(dtype)

    def mk(s, d=dt):
        x = rng.standard_normal(size=s)
        if jnp.dtype(d) == jnp.int8:
            return jnp.asarray(np.clip(np.round(x * 40), -127, 127), jnp.int8)
        return jnp.asarray(x, d)

    if kernel == "batched_gram":
        return (mk(shape),)
    if kernel == "batched_gram_mixed":
        N, d, k, r = shape
        return (mk((N, d, k), jnp.int8),
                jnp.abs(mk((N, k), jnp.float32)) + 0.1,
                mk((N, d, r), jnp.float32))
    if kernel == "batched_lowrank_apply":
        N, d, ell, n = shape
        return (mk((N, d, ell)), mk((N, ell), jnp.float32),
                jnp.abs(mk((N,), jnp.float32)), mk((N, d, n), jnp.float32))
    if kernel == "batched_project_quantize":
        N, d, k, r, e = shape
        return (mk((N, d, k), jnp.int8), mk((N, k, e), jnp.float32),
                mk((N, d, r), jnp.float32), mk((N, r, e), jnp.float32))
    raise ValueError(f"unknown kernel {kernel!r}")


def _runner(kernel: str):
    """``fn(config, *operands)`` invoking the pallas kernel with the
    candidate's static tile args.  Kernel modules import lazily (the
    registry imports this module at its own import time)."""
    from repro.kernels.gram import kernel as gram_kernel
    from repro.kernels.lowrank import kernel as lowrank_kernel
    interp = _interpret()
    if kernel == "batched_gram":
        return lambda c, a: gram_kernel.batched_gram_pallas(
            a, bk=c.bk, bd=c.bd, bn_stack=c.bn_stack, interpret=interp)
    if kernel == "batched_gram_mixed":
        return lambda c, vq, colw, a: gram_kernel.batched_gram_mixed_pallas(
            vq, colw, a, bd=c.bd, bn_stack=c.bn_stack, interpret=interp)
    if kernel == "batched_lowrank_apply":
        return lambda c, u, co, b, g: \
            lowrank_kernel.batched_lowrank_apply_pallas(
                u, co, b, g, bn=c.bn, bn_stack=c.bn_stack, interpret=interp)
    if kernel == "batched_project_quantize":
        return lambda c, vq, wt, a, wb: \
            lowrank_kernel.batched_project_quantize_pallas(
                vq, wt, a, wb, bn_stack=c.bn_stack, interpret=interp)
    raise ValueError(f"unknown kernel {kernel!r}")


def tune(kernel: str, shape: tuple, dtype, *, repeats: int = 3
         ) -> tuple[TileConfig, dict]:
    """Measured search: time every candidate, return (winner, table).

    The table maps TileConfig -> best-of-``repeats`` seconds; candidates
    that fail to compile/execute are recorded as ``inf`` and never win.
    The default config is measured first and wins ties, so a tuned run is
    never slower than untuned modulo timer noise.
    """
    ops = _operands(kernel, shape, dtype)
    fn = _runner(kernel)
    table: dict = {}
    for cand in candidates(kernel, shape):
        try:
            jax.block_until_ready(fn(cand, *ops))  # compile + warm
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(cand, *ops))
                best = min(best, time.perf_counter() - t0)
            table[cand] = best
        except Exception:
            table[cand] = float("inf")
    winner = min(table, key=lambda c: (table[c], candidates(
        kernel, shape).index(c)))
    if table[winner] == float("inf"):
        raise RuntimeError(
            f"every candidate failed for {kernel} {shape} {dtype}")
    return winner, table


def tune_into_cache(specs, *, path: Optional[str] = None) -> dict:
    """Force-tune a list of ``(kernel, shape, dtype)`` specs into the
    in-process cache (and ``path`` if given), returning {key: TileConfig}.
    Benchmarks use this to flip the engine onto tuned configs without
    touching the committed fixture."""
    st = _resolve()
    out = {}
    for kernel, shape, dtype in specs:
        key = key_for(kernel, shape, dtype)
        if key not in st["entries"]:
            st["entries"][key], _ = tune(kernel, shape, dtype)
        out[key] = st["entries"][key]
    st["epoch"] += 1  # re-intern KernelSets against the new snapshot
    if path is not None:
        save(path)
    return out


# ------------------------------------------------------- persistence / schema


def save(path: Optional[str] = None) -> str:
    st = _resolve()
    path = path or st["path"]
    data = {"version": CACHE_VERSION,
            "entries": {k: dict(v._asdict(), us=None)
                        for k, v in sorted(st["entries"].items())}}
    # drop the informational 'us' slot (kept for hand-edited caches)
    for v in data["entries"].values():
        v.pop("us")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path


def validate_cache(data: Any) -> list:
    """Schema check for a loaded cache dict: every entry's key parses, the
    kernel is known, and the config lies inside the candidate space for its
    key's shape (the committed fixture is CI-gated on this)."""
    problems = []
    if not isinstance(data, dict):
        return [f"cache root must be an object, got {type(data).__name__}"]
    if data.get("version") != CACHE_VERSION:
        problems.append(f"version {data.get('version')!r} != {CACHE_VERSION}")
    entries = data.get("entries", {})
    if not isinstance(entries, dict):
        return problems + ["'entries' must be an object"]
    for key, v in entries.items():
        try:
            _, kernel, shape, dtype = parse_key(key)
        except ValueError as e:
            problems.append(str(e))
            continue
        if kernel not in KERNELS:
            problems.append(f"{key}: unknown kernel {kernel!r}")
            continue
        try:
            jnp.dtype(dtype)
        except TypeError:
            problems.append(f"{key}: unknown dtype {dtype!r}")
            continue
        if not isinstance(v, dict) or \
                set(v) - {"bn_stack", "bk", "bd", "bn", "us"}:
            problems.append(f"{key}: unexpected config fields {sorted(v)}")
            continue
        try:
            cfg = TileConfig(bn_stack=int(v["bn_stack"]), bk=int(v["bk"]),
                             bd=int(v["bd"]), bn=int(v["bn"]))
        except (KeyError, TypeError, ValueError):
            problems.append(f"{key}: config fields must be 4 ints")
            continue
        if cfg not in candidates(kernel, shape):
            problems.append(
                f"{key}: config {tuple(cfg)} outside the candidate space "
                f"for shape {shape}")
    return problems


# ------------------------------------------------------------------------ CLI


def _main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.kernels.autotune",
        description="Tune-cache maintenance for the batched Pallas kernels.")
    sub = p.add_subparsers(dest="cmd", required=True)
    v = sub.add_parser("validate", help="schema-check a cache file")
    v.add_argument("--cache", default=DEFAULT_CACHE_PATH)
    t = sub.add_parser("tune", help="measure one (kernel, shape, dtype) and "
                                    "write the winner into a cache file")
    t.add_argument("--kernel", required=True, choices=KERNELS)
    t.add_argument("--shape", required=True,
                   help="operand dims, e.g. 32x32x40")
    t.add_argument("--dtype", default="float32")
    t.add_argument("--cache", default=DEFAULT_CACHE_PATH)
    s = sub.add_parser("show", help="print the resolved entries")
    s.add_argument("--cache", default=None)
    args = p.parse_args(argv)

    if args.cmd == "validate":
        if not os.path.exists(args.cache):
            print(f"FAIL: no cache at {args.cache}")
            return 1
        with open(args.cache) as f:
            data = json.load(f)
        problems = validate_cache(data)
        for pr in problems:
            print(f"FAIL: {pr}")
        if not problems:
            print(f"tune cache OK: {len(data.get('entries', {}))} entries "
                  f"validated against the candidate-space schema")
        return 1 if problems else 0

    if args.cmd == "tune":
        shape = tuple(int(x) for x in args.shape.split("x"))
        reload(path=args.cache, mode="force")
        best, table = tune(args.kernel, shape, args.dtype)
        key = key_for(args.kernel, shape, args.dtype)
        _resolve()["entries"][key] = best
        save(args.cache)
        ranked = sorted(table.items(), key=lambda kv: kv[1])
        for cfg, t_s in ranked[:5]:
            mark = " <-- saved" if cfg == best else ""
            print(f"{tuple(cfg)}: {t_s * 1e6:.1f}us{mark}")
        print(f"wrote {key} to {args.cache}")
        return 0

    if args.cache:
        reload(path=args.cache)
    for k, cfg in sorted(_resolve()["entries"].items()):
        print(f"{k}: {tuple(cfg)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
