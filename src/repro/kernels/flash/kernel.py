"""Pallas TPU kernel: causal flash attention forward (GQA-aware).

Training-forward hotspot. Online-softmax tiling: each (q-block, kv-block)
pair streams K/V tiles through VMEM while the (bq, hd) output accumulator,
running max m and normalizer l live in VMEM scratch across the kv dimension
(sequential innermost grid axis). Never materializes the (S, S) logits.

Causal blocks entirely above the diagonal are skipped via pl.when.
GQA: the kv-head index map divides the query-head grid index by the group
size, so no KV repetition is materialized in HBM.

Grid: (batch, q_heads, q_tiles, kv_tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, sm_scale: float, bq: int, bk: int, n_kv_tiles: int,
                  causal: bool, kv_valid: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk
    # Skip fully-masked blocks (strictly above the causal diagonal).
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0]                     # (bq, hd)
        k = k_ref[0, 0]                     # (bk, hd)
        v = v_ref[0, 0]                     # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                    # (bq, bk)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            s = jnp.where(rows >= cols, s, NEG_INF)
        if kv_valid % bk:                   # mask padded KV tail
            s = jnp.where(cols < kv_valid, s, NEG_INF)

        m_prev = m_ref[...]                 # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)              # (bq, bk)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_kv_tiles - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, bq: int = 128, bk: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, S, hd); k, v: (B, Hkv, S, hd) with Hq % Hkv == 0."""
    B, Hq, S, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    bq = min(bq, S)
    bk = min(bk, Sk)
    pq = (-S) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    Sp, Skp = q.shape[2], k.shape[2]
    n_q = Sp // bq
    n_kv = Skp // bk
    sm_scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_flash_kernel, sm_scale=sm_scale, bq=bq, bk=bk,
                               n_kv_tiles=n_kv, causal=causal, kv_valid=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]
