"""Jitted public wrapper for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash.kernel import flash_attention_pallas
from repro.kernels.flash.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True) -> jnp.ndarray:
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=not _on_tpu())
