"""Jitted public wrapper for flash attention.

Interpret-vs-Mosaic comes from the kernel registry's cached platform probe —
resolved once per process, not re-evaluated per call at trace time.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.flash.kernel import flash_attention_pallas


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True) -> jnp.ndarray:
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=registry.interpret_mode())
