"""Pure-jnp oracle for causal flash attention (GQA)."""
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True) -> jnp.ndarray:
    """q: (B, Hq, S, hd); k, v: (B, Hkv, Sk, hd)."""
    B, Hq, S, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / (hd ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, Sk), bool), k=Sk - S)
        s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
