"""Pallas TPU kernel: Gram matrix C = A^T A with tiled reduction.

This is the tall-skinny contraction at the heart of every FD update
(DESIGN.md §3): M = [sqrt(beta2) B, G] is (d, ell+r) and we need its
(ell+r, ell+r) Gram. The reduction dim d streams through VMEM in ``bd``
tiles while each (bk x bk) output tile stays VMEM-resident and accumulates —
MXU-aligned when tiles are multiples of 128 (default ell=256 is).

Grid: (k_tiles_i, k_tiles_j, d_tiles); d is the innermost (sequential)
dimension so the output block revision is legal ("arbitrary" semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(a_i_ref, a_j_ref, out_ref, *, n_d_tiles: int):
    di = pl.program_id(2)

    @pl.when(di == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a_i = a_i_ref[...]  # (bd, bk)
    a_j = a_j_ref[...]  # (bd, bk)
    out_ref[...] += jax.lax.dot_general(
        a_i, a_j, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "bd", "interpret"))
def gram_pallas(a: jnp.ndarray, *, bk: int = 128, bd: int = 256,
                interpret: bool = True) -> jnp.ndarray:
    """C = A^T A for A of shape (d, k). Pads to tile multiples."""
    d, k = a.shape
    bk = min(bk, max(k, 1))
    bd = min(bd, max(d, 1))
    pk = (-k) % bk
    pd = (-d) % bd
    if pk or pd:
        a = jnp.pad(a, ((0, pd), (0, pk)))
    dp, kp = a.shape
    n_d_tiles = dp // bd
    grid = (kp // bk, kp // bk, n_d_tiles)

    out = pl.pallas_call(
        functools.partial(_gram_kernel, n_d_tiles=n_d_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, bk), lambda i, j, di: (di, i)),
            pl.BlockSpec((bd, bk), lambda i, j, di: (di, j)),
        ],
        out_specs=pl.BlockSpec((bk, bk), lambda i, j, di: (i, j)),
        # accumulate in f32 regardless of input dtype (MXU-style)
        out_shape=jax.ShapeDtypeStruct((kp, kp), jnp.float32),
        interpret=interpret,
    )(a, a)
    return out[:k, :k]  # f32 accumulator result (FD consumes f32)
