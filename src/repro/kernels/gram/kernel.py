"""Pallas TPU kernels: Gram matrix C = A^T A with tiled reduction.

This is the tall-skinny contraction at the heart of every FD update
(DESIGN.md §3): M = [sqrt(beta2) B, G] is (d, ell+r) and we need its
(ell+r, ell+r) Gram. The reduction dim d streams through VMEM in ``bd``
tiles while each (bk x bk) output tile stays VMEM-resident and accumulates —
MXU-aligned when tiles are multiples of 128 (default ell=256 is).  Inputs of
any float dtype (bf16/fp16/f32) are upcast in-kernel so the accumulator is
always f32.

Single-block grid: (k_tiles_i, k_tiles_j, d_tiles); d is the innermost
(sequential) dimension so the output block revision is legal ("arbitrary"
semantics).

Batched grid (``batched_gram_pallas``) — the pooled-stack entry point: the
input is one packed ``(N, d, k)`` pool of same-shaped blocks (core/pool.py)
and the pool dim N joins the grid directly instead of being vmapped over:

    grid = (N / bn_stack, k_tiles_i, k_tiles_j, d_tiles)

One program instance owns ``bn_stack`` blocks' (bk x bk) output tile (default
1 — one program per block x output tile) and streams their shared d range
through VMEM exactly like the single-block kernel; d stays innermost so each
(n, i, j) accumulator is revisited sequentially.  N ragged against
``bn_stack`` is zero-padded (a zero block contributes a zero Gram) and
sliced off, as are ragged k/d tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(a_i_ref, a_j_ref, out_ref, *, n_d_tiles: int):
    di = pl.program_id(2)

    @pl.when(di == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # upcast before the dot: bf16/fp16 inputs accumulate in f32 (MXU-style)
    a_i = a_i_ref[...].astype(jnp.float32)  # (bd, bk)
    a_j = a_j_ref[...].astype(jnp.float32)  # (bd, bk)
    out_ref[...] += jax.lax.dot_general(
        a_i, a_j, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bk", "bd", "interpret"))
def gram_pallas(a: jnp.ndarray, *, bk: int = 128, bd: int = 256,
                interpret: bool = True) -> jnp.ndarray:
    """C = A^T A for A of shape (d, k). Pads to tile multiples."""
    d, k = a.shape
    bk = min(bk, max(k, 1))
    bd = min(bd, max(d, 1))
    pk = (-k) % bk
    pd = (-d) % bd
    if pk or pd:
        a = jnp.pad(a, ((0, pd), (0, pk)))
    dp, kp = a.shape
    n_d_tiles = dp // bd
    grid = (kp // bk, kp // bk, n_d_tiles)

    out = pl.pallas_call(
        functools.partial(_gram_kernel, n_d_tiles=n_d_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, bk), lambda i, j, di: (di, i)),
            pl.BlockSpec((bd, bk), lambda i, j, di: (di, j)),
        ],
        out_specs=pl.BlockSpec((bk, bk), lambda i, j, di: (i, j)),
        # accumulate in f32 regardless of input dtype (MXU-style)
        out_shape=jax.ShapeDtypeStruct((kp, kp), jnp.float32),
        interpret=interpret,
    )(a, a)
    return out[:k, :k]  # f32 accumulator result (FD consumes f32)


def _batched_gram_kernel(a_i_ref, a_j_ref, out_ref):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a_i = a_i_ref[...].astype(jnp.float32)  # (bn_stack, bd, bk)
    a_j = a_j_ref[...].astype(jnp.float32)
    # per-block A^T A: contract the streamed d tile, batch the pool dim
    out_ref[...] += jax.lax.dot_general(
        a_i, a_j, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("bk", "bd", "bn_stack", "interpret"))
def batched_gram_pallas(a: jnp.ndarray, *, bk: int = 128, bd: int = 256,
                        bn_stack: int = 1,
                        interpret: bool = True) -> jnp.ndarray:
    """C[n] = A[n]^T A[n] for a packed pool stack A of shape (N, d, k).

    The pool dim N lives on the Pallas grid (``bn_stack`` blocks per program,
    default one program per block x output tile) — no vmap over the
    single-block kernel.  Ragged N/d/k are zero-padded and sliced off.
    """
    N, d, k = a.shape
    if N == 0:
        # empty pool group: a 0-sized grid dim is undefined behaviour in
        # some lowerings, and the result is shape-determined anyway
        return jnp.zeros((0, k, k), jnp.float32)
    bk = min(bk, max(k, 1))
    bd = min(bd, max(d, 1))
    bn_stack = min(bn_stack, max(N, 1))
    pN = (-N) % bn_stack
    pk = (-k) % bk
    pd = (-d) % bd
    if pN or pk or pd:
        a = jnp.pad(a, ((0, pN), (0, pd), (0, pk)))
    Np, dp, kp = a.shape
    grid = (Np // bn_stack, kp // bk, kp // bk, dp // bd)

    out = pl.pallas_call(
        _batched_gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn_stack, bd, bk), lambda n, i, j, di: (n, di, i)),
            pl.BlockSpec((bn_stack, bd, bk), lambda n, i, j, di: (n, di, j)),
        ],
        out_specs=pl.BlockSpec((bn_stack, bk, bk),
                               lambda n, i, j, di: (n, i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, kp, kp), jnp.float32),
        interpret=interpret,
    )(a, a)
    return out[:N, :k, :k]


def _batched_gram_mixed_kernel(vq_ref, a_ref, out_ref):
    di = pl.program_id(1)

    @pl.when(di == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # the int8 eigenvector stack dequantizes in-registers: the astype IS the
    # dequantize (per-block scale + per-column ladder weights are folded in
    # outside the kernel, on the small (N, k+r, k+r) output)
    b = vq_ref[...].astype(jnp.float32)       # (bn_stack, bd, k)
    a = a_ref[...].astype(jnp.float32)        # (bn_stack, bd, r)
    m = jnp.concatenate([b, a], axis=2)       # in-register, never HBM
    out_ref[...] += jax.lax.dot_general(
        m, m, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bd", "bn_stack", "interpret"))
def batched_gram_mixed_pallas(vq: jnp.ndarray, colw: jnp.ndarray,
                              a: jnp.ndarray, *, bd: int = 256,
                              bn_stack: int = 1,
                              interpret: bool = True) -> jnp.ndarray:
    """Gram of the mixed FD stack ``M = [dequant(vq) * colw, A]`` without
    ever materializing the dequantized ``(N, d, k)`` f32 eigenvector stack.

    vq: (N, d, k) int8 quantized eigenvectors, colw: (N, k) f32 per-column
    weights (per-block quantization scale x sqrt(beta2 * s) folded
    together), a: (N, d, r) f32 new factors.  Returns (N, k+r, k+r) f32.

    The kernel accumulates ``C0 = [V, A]^T [V, A]`` with the int8 upcast
    happening in-registers (grid = (N/bn_stack, d/bd); the whole (k+r)^2
    output tile stays VMEM-resident per block — fine for the pool shapes
    the engine produces, where k+r <= block_size + rank).  The exact column
    weighting ``C = D C0 D`` with ``D = diag([colw, 1])`` is applied
    outside on the small output: elementwise f32, no d-sized traffic.
    """
    N, d, k = vq.shape
    Na, da, r = a.shape
    assert (N, d) == (Na, da), (vq.shape, a.shape)
    K = k + r
    if N == 0:
        return jnp.zeros((0, K, K), jnp.float32)
    bd = min(bd, max(d, 1))
    bn_stack = min(bn_stack, max(N, 1))
    pN = (-N) % bn_stack
    pd = (-d) % bd
    if pN or pd:
        vq = jnp.pad(vq, ((0, pN), (0, pd), (0, 0)))
        a = jnp.pad(a, ((0, pN), (0, pd), (0, 0)))
    Np, dp, _ = vq.shape

    out = pl.pallas_call(
        _batched_gram_mixed_kernel,
        grid=(Np // bn_stack, dp // bd),
        in_specs=[
            pl.BlockSpec((bn_stack, bd, k), lambda n, di: (n, di, 0)),
            pl.BlockSpec((bn_stack, bd, r), lambda n, di: (n, di, 0)),
        ],
        out_specs=pl.BlockSpec((bn_stack, K, K), lambda n, di: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, K, K), jnp.float32),
        interpret=interpret,
    )(vq, a)
    out = out[:N]
    w = jnp.concatenate(
        [colw.astype(jnp.float32), jnp.ones((N, r), jnp.float32)], axis=1)
    return out * w[:, :, None] * w[:, None, :]
