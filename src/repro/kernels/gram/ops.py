"""Jitted public wrapper for the gram kernel.

On CPU (this container) the kernel executes in interpret mode for
correctness validation; on TPU the same pallas_call compiles to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gram.kernel import gram_pallas
from repro.kernels.gram.ref import gram_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gram(a: jnp.ndarray) -> jnp.ndarray:
    """C = A^T A. Kernel on TPU, interpret-mode kernel elsewhere."""
    return gram_pallas(a, interpret=not _on_tpu())
