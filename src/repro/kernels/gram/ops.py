"""Jitted public wrappers for the gram kernels.

Interpret-vs-Mosaic is resolved ONCE by the kernel registry (platform probe
cached at first use — not re-evaluated per call at trace time); on CPU the
kernels execute in interpret mode for correctness validation, on TPU the
same pallas_call compiles to Mosaic.  Backend selection (pallas vs the jnp
refs) lives in ``repro.kernels.registry.get_kernels``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import registry


def gram(a: jnp.ndarray) -> jnp.ndarray:
    """C = A^T A via the Pallas kernel (interpret mode off-TPU)."""
    return registry.get_kernels("pallas").gram(a)


def batched_gram(a: jnp.ndarray) -> jnp.ndarray:
    """C[n] = A[n]^T A[n] over a (N, d, k) pool stack, grid-over-N."""
    return registry.get_kernels("pallas").batched_gram(a)
