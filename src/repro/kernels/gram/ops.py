"""Jitted public wrappers for the gram kernels.

Interpret-vs-Mosaic is resolved ONCE by the kernel registry (platform probe
cached at first use — not re-evaluated per call at trace time); on CPU the
kernels execute in interpret mode for correctness validation, on TPU the
same pallas_call compiles to Mosaic.  Backend selection (pallas vs the jnp
refs) lives in ``repro.kernels.registry.get_kernels``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import autotune, registry


def gram(a: jnp.ndarray) -> jnp.ndarray:
    """C = A^T A via the Pallas kernel (interpret mode off-TPU)."""
    return registry.get_kernels("pallas").gram(a)


def batched_gram(a: jnp.ndarray, *,
                 config: Optional[autotune.TileConfig] = None) -> jnp.ndarray:
    """C[n] = A[n]^T A[n] over a (N, d, k) pool stack, grid-over-N.

    ``config`` pins an explicit TileConfig; omitted, the registry resolves
    one per shape from the tune cache (default tiles on a miss) — no call
    site hardcodes ``bn_stack`` anymore.
    """
    return registry.get_kernels("pallas").batched_gram(a, config=config)


def batched_gram_mixed(vq: jnp.ndarray, colw: jnp.ndarray, a: jnp.ndarray, *,
                       config: Optional[autotune.TileConfig] = None
                       ) -> jnp.ndarray:
    """Gram of ``[dequant(vq) * colw, A]`` with the int8 stack upcast
    in-registers; see kernels/gram/kernel.py."""
    return registry.get_kernels("pallas").batched_gram_mixed(
        vq, colw, a, config=config)
