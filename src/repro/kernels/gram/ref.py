"""Pure-jnp oracles for the gram kernels (also the "xla" backend entries).

``batched_gram_ref`` is written as the single ``dot_general`` that
``jax.vmap(gram_ref)`` lowers to, so the pooled engine's XLA path stays
bitwise-identical to the per-leaf vmap dispatch it replaced.
"""
import jax
import jax.numpy as jnp


def gram_ref(a: jnp.ndarray) -> jnp.ndarray:
    a32 = a.astype(jnp.float32)
    return a32.T @ a32


def batched_gram_ref(a: jnp.ndarray) -> jnp.ndarray:
    """C[n] = A[n]^T A[n] for a (N, d, k) stack; f32 accumulation."""
    a32 = a.astype(jnp.float32)
    return jax.lax.dot_general(a32, a32, (((1,), (1,)), ((0,), (0,))))
