"""Pure-jnp oracles for the gram kernels (also the "xla" backend entries).

``batched_gram_ref`` is written as the single ``dot_general`` that
``jax.vmap(gram_ref)`` lowers to, so the pooled engine's XLA path stays
bitwise-identical to the per-leaf vmap dispatch it replaced.
"""
import jax
import jax.numpy as jnp


def gram_ref(a: jnp.ndarray) -> jnp.ndarray:
    a32 = a.astype(jnp.float32)
    return a32.T @ a32


def batched_gram_ref(a: jnp.ndarray) -> jnp.ndarray:
    """C[n] = A[n]^T A[n] for a (N, d, k) stack; f32 accumulation."""
    a32 = a.astype(jnp.float32)
    return jax.lax.dot_general(a32, a32, (((1,), (1,)), ((0,), (0,))))


def batched_gram_mixed_ref(vq: jnp.ndarray, colw: jnp.ndarray,
                           a: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the fused mixed Gram: vq (N, d, k) int8 eigenvectors,
    colw (N, k) f32 per-column weights (block scale x sqrt(beta2*s)),
    a (N, d, r) f32 new factors -> (N, k+r, k+r) f32 Gram of [vq*colw, a].

    Mirrors the kernel's math exactly: the unweighted Gram of [V, A] first,
    column weights applied on the small output (not on the d-sized stack).
    """
    N, _, k = vq.shape
    r = a.shape[-1]
    m = jnp.concatenate([vq.astype(jnp.float32), a.astype(jnp.float32)],
                        axis=2)
    c0 = jax.lax.dot_general(m, m, (((1,), (1,)), ((0,), (0,))))
    w = jnp.concatenate(
        [colw.astype(jnp.float32), jnp.ones((N, r), jnp.float32)], axis=1)
    return c0 * w[:, :, None] * w[:, None, :]
