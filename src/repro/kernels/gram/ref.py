"""Pure-jnp oracle for the gram kernel."""
import jax.numpy as jnp


def gram_ref(a: jnp.ndarray) -> jnp.ndarray:
    a32 = a.astype(jnp.float32)
    return a32.T @ a32
