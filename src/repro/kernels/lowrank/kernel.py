"""Pallas TPU kernels: fused low-rank + diagonal inverse-root apply.

The Sketchy preconditioner application (DESIGN.md §3):

    Y = base * G + U @ diag(coeffs) @ (U^T @ G)

U is (d, ell) with ell <= 256 by default, so U (1024 x 256 fp32 = 1 MiB) and
one (d, bn) tile of G stay VMEM-resident together; both matmuls and the
diagonal scale fuse into a single pass over G — HBM traffic is exactly
read(G) + read(U) + write(Y) instead of three round trips for the unfused
projection / scale / expand chain.  bf16/fp16 operands are upcast in-kernel
so both matmuls accumulate in f32.

Single-block grid: 1-D over column tiles of G.

Batched grid (``batched_lowrank_apply_pallas``) — the pooled-stack entry
point: every operand gains a leading pool dim N (U: (N, d, ell), coeffs:
(N, ell), base: (N,), G: (N, d, n)) and N joins the grid directly:

    grid = (N / bn_stack, n_tiles)

One program fuses the full low-rank apply for ``bn_stack`` blocks' (d, bn)
column tile of G (default 1 — one program per block x column tile), keeping
those blocks' U factors VMEM-resident.  N ragged against ``bn_stack`` is
zero-padded (zero U/base produce a zero output block) and sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lowrank_kernel(u_ref, coeffs_ref, base_ref, g_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)   # (d, ell)
    g = g_ref[...].astype(jnp.float32)   # (d, bn)
    coeffs = coeffs_ref[...]             # (1, ell) f32
    base = base_ref[0, 0]
    # P = U^T G : (ell, bn)
    proj = jax.lax.dot_general(u, g, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    proj = proj * coeffs.reshape(-1, 1)
    expand = jax.lax.dot_general(u, proj, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    out_ref[...] = (base * g + expand).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def lowrank_apply_pallas(u: jnp.ndarray, coeffs: jnp.ndarray, base: jnp.ndarray,
                         g: jnp.ndarray, *, bn: int = 256,
                         interpret: bool = True) -> jnp.ndarray:
    """Y = base*G + U diag(coeffs) U^T G.  u: (d, ell), g: (d, n)."""
    d, ell = u.shape
    dg, n = g.shape
    assert d == dg, (u.shape, g.shape)
    bn = min(bn, max(n, 1))
    pn = (-n) % bn
    if pn:
        g = jnp.pad(g, ((0, 0), (0, pn)))
    np_ = g.shape[1]
    coeffs2d = coeffs.reshape(1, ell).astype(jnp.float32)
    base2d = jnp.asarray(base, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _lowrank_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((d, ell), lambda j: (0, 0)),
            pl.BlockSpec((1, ell), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
            pl.BlockSpec((d, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((d, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((d, np_), g.dtype),
        interpret=interpret,
    )(u, coeffs2d, base2d, g)
    return out[:, :n]


def _batched_lowrank_kernel(u_ref, coeffs_ref, base_ref, g_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)   # (bn_stack, d, ell)
    g = g_ref[...].astype(jnp.float32)   # (bn_stack, d, bn)
    coeffs = coeffs_ref[...]             # (bn_stack, ell) f32
    base = base_ref[...]                 # (bn_stack, 1) f32
    # P[n] = U[n]^T G[n] : (bn_stack, ell, bn)
    proj = jax.lax.dot_general(u, g, (((1,), (1,)), ((0,), (0,))),
                               preferred_element_type=jnp.float32)
    proj = proj * coeffs[:, :, None]
    expand = jax.lax.dot_general(u, proj, (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)
    out_ref[...] = (base[:, :, None] * g + expand).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bn_stack", "interpret"))
def batched_lowrank_apply_pallas(u: jnp.ndarray, coeffs: jnp.ndarray,
                                 base: jnp.ndarray, g: jnp.ndarray, *,
                                 bn: int = 256, bn_stack: int = 1,
                                 interpret: bool = True) -> jnp.ndarray:
    """Y[n] = base[n]*G[n] + U[n] diag(coeffs[n]) U[n]^T G[n] over a pool.

    u: (N, d, ell), coeffs: (N, ell), base: (N,), g: (N, d, n).  The pool dim
    N lives on the Pallas grid — no vmap over the single-block kernel.
    """
    N, d, ell = u.shape
    Ng, dg, n = g.shape
    assert (N, d) == (Ng, dg), (u.shape, g.shape)
    if N == 0:
        # empty pool group: nothing to apply (0-sized grid dims are
        # undefined behaviour in some lowerings)
        return jnp.zeros((0, d, n), g.dtype)
    bn = min(bn, max(n, 1))
    bn_stack = min(bn_stack, max(N, 1))
    pN = (-N) % bn_stack
    pn = (-n) % bn
    coeffs2d = coeffs.reshape(N, ell).astype(jnp.float32)
    base2d = jnp.asarray(base, jnp.float32).reshape(N, 1)
    if pN or pn:
        u = jnp.pad(u, ((0, pN), (0, 0), (0, 0)))
        g = jnp.pad(g, ((0, pN), (0, 0), (0, pn)))
        coeffs2d = jnp.pad(coeffs2d, ((0, pN), (0, 0)))
        base2d = jnp.pad(base2d, ((0, pN), (0, 0)))
    Np, _, np_ = g.shape

    out = pl.pallas_call(
        _batched_lowrank_kernel,
        grid=(Np // bn_stack, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn_stack, d, ell), lambda nb, j: (nb, 0, 0)),
            pl.BlockSpec((bn_stack, ell), lambda nb, j: (nb, 0)),
            pl.BlockSpec((bn_stack, 1), lambda nb, j: (nb, 0)),
            pl.BlockSpec((bn_stack, d, bn), lambda nb, j: (nb, 0, j)),
        ],
        out_specs=pl.BlockSpec((bn_stack, d, bn), lambda nb, j: (nb, 0, j)),
        out_shape=jax.ShapeDtypeStruct((Np, d, np_), g.dtype),
        interpret=interpret,
    )(u, coeffs2d, base2d, g)
    return out[:N, :, :n]


# int8 range of the quantized-pool storage format; must mirror
# core/quantize.py (_INT8_MAX) so the fused requantize epilogue below is
# interchangeable with quantize.quantize_stack's round-to-nearest path.
_INT8_MAX = 127.0


def _batched_project_quantize_kernel(vq_ref, wtop_ref, a_ref, wbot_ref,
                                     values_ref, scale_ref):
    # U_new = dequant(Vq) @ W_top + A @ W_bot, with the per-block dequant
    # scale and the eigenvalue-ladder column weights pre-folded into W_top
    # (both are per-column of the SMALL factor, so folding is exact); the
    # int8 upcast happens in-registers, and the freshly projected factor is
    # re-quantized before it ever leaves the kernel — the f32 (d, ell)
    # stack exists only in VMEM scratch, never in HBM.
    v = vq_ref[...].astype(jnp.float32)       # (bn_stack, d, k)
    un = jax.lax.dot_general(v, wtop_ref[...],
                             (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    un += jax.lax.dot_general(a_ref[...].astype(jnp.float32), wbot_ref[...],
                              (((2,), (1,)), ((0,), (0,))),
                              preferred_element_type=jnp.float32)
    absmax = jnp.max(jnp.abs(un), axis=(1, 2), keepdims=True)
    scale = jnp.where(absmax > 0, absmax / _INT8_MAX, 1.0)
    scale_ref[...] = scale
    values_ref[...] = jnp.clip(jnp.round(un / scale),
                               -_INT8_MAX, _INT8_MAX).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bn_stack", "interpret"))
def batched_project_quantize_pallas(vq: jnp.ndarray, w_top: jnp.ndarray,
                                    a: jnp.ndarray, w_bot: jnp.ndarray, *,
                                    bn_stack: int = 1,
                                    interpret: bool = True
                                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused FD write-back epilogue for int8 pool storage.

    Computes ``U_new = dequant(vq) @ w_top + a @ w_bot`` per pool block and
    re-quantizes it in the same kernel: returns ``(values int8 (N, d, e),
    scale f32 (N, 1, 1))`` matching the ``QuantizedPool`` storage layout.

    vq: (N, d, k) int8, w_top: (N, k, e) f32 (quantization scale + ladder
    weights folded in by the caller), a: (N, d, r) f32, w_bot: (N, r, e)
    f32.  One grid step owns ``bn_stack`` whole blocks (the per-block
    absmax needs the full (d, e) factor resident — d x e stays comfortably
    in VMEM for the engine's block sizes; round-to-nearest is used because
    the eigenvector factor is fully recomputed each refresh, not EMA-
    accumulated, so stochastic rounding buys nothing here).
    """
    N, d, k = vq.shape
    e = w_top.shape[-1]
    r = a.shape[-1]
    assert w_top.shape == (N, k, e), (vq.shape, w_top.shape)
    assert a.shape[:2] == (N, d) and w_bot.shape == (N, r, e), \
        (a.shape, w_bot.shape)
    if N == 0:
        return (jnp.zeros((0, d, e), jnp.int8),
                jnp.ones((0, 1, 1), jnp.float32))
    bn_stack = min(bn_stack, max(N, 1))
    pN = (-N) % bn_stack
    if pN:
        vq = jnp.pad(vq, ((0, pN), (0, 0), (0, 0)))
        w_top = jnp.pad(w_top, ((0, pN), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, pN), (0, 0), (0, 0)))
        w_bot = jnp.pad(w_bot, ((0, pN), (0, 0), (0, 0)))
    Np = vq.shape[0]

    values, scale = pl.pallas_call(
        _batched_project_quantize_kernel,
        grid=(Np // bn_stack,),
        in_specs=[
            pl.BlockSpec((bn_stack, d, k), lambda nb: (nb, 0, 0)),
            pl.BlockSpec((bn_stack, k, e), lambda nb: (nb, 0, 0)),
            pl.BlockSpec((bn_stack, d, r), lambda nb: (nb, 0, 0)),
            pl.BlockSpec((bn_stack, r, e), lambda nb: (nb, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn_stack, d, e), lambda nb: (nb, 0, 0)),
            pl.BlockSpec((bn_stack, 1, 1), lambda nb: (nb, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np, d, e), jnp.int8),
            jax.ShapeDtypeStruct((Np, 1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(vq, w_top, a, w_bot)
    return values[:N], scale[:N]
