"""Pallas TPU kernel: fused low-rank + diagonal inverse-root apply.

The Sketchy preconditioner application (DESIGN.md §3):

    Y = base * G + U @ diag(coeffs) @ (U^T @ G)

U is (d, ell) with ell <= 256 by default, so U (1024 x 256 fp32 = 1 MiB) and
one (d, bn) tile of G stay VMEM-resident together; both matmuls and the
diagonal scale fuse into a single pass over G — HBM traffic is exactly
read(G) + read(U) + write(Y) instead of three round trips for the unfused
projection / scale / expand chain.

Grid: 1-D over column tiles of G.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lowrank_kernel(u_ref, coeffs_ref, base_ref, g_ref, out_ref):
    u = u_ref[...]                  # (d, ell)
    g = g_ref[...]                  # (d, bn)
    coeffs = coeffs_ref[...]        # (1, ell)
    base = base_ref[0, 0]
    # P = U^T G : (ell, bn)
    proj = jax.lax.dot_general(u, g, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    proj = proj * coeffs.reshape(-1, 1)
    expand = jax.lax.dot_general(u, proj, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    out_ref[...] = (base * g.astype(jnp.float32) + expand).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def lowrank_apply_pallas(u: jnp.ndarray, coeffs: jnp.ndarray, base: jnp.ndarray,
                         g: jnp.ndarray, *, bn: int = 256,
                         interpret: bool = True) -> jnp.ndarray:
    """Y = base*G + U diag(coeffs) U^T G.  u: (d, ell), g: (d, n)."""
    d, ell = u.shape
    dg, n = g.shape
    assert d == dg, (u.shape, g.shape)
    bn = min(bn, max(n, 1))
    pn = (-n) % bn
    if pn:
        g = jnp.pad(g, ((0, 0), (0, pn)))
    np_ = g.shape[1]
    coeffs2d = coeffs.reshape(1, ell).astype(jnp.float32)
    base2d = jnp.asarray(base, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _lowrank_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((d, ell), lambda j: (0, 0)),
            pl.BlockSpec((1, ell), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
            pl.BlockSpec((d, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((d, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((d, np_), g.dtype),
        interpret=interpret,
    )(u, coeffs2d, base2d, g)
    return out[:, :n]
