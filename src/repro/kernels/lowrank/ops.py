"""Jitted public wrapper for the fused low-rank preconditioner apply."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lowrank.kernel import lowrank_apply_pallas
from repro.kernels.lowrank.ref import lowrank_apply_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def lowrank_apply(u: jnp.ndarray, coeffs: jnp.ndarray, base,
                  g: jnp.ndarray) -> jnp.ndarray:
    return lowrank_apply_pallas(u, coeffs, base, g, interpret=not _on_tpu())
