"""Jitted public wrappers for the fused low-rank preconditioner apply.

Interpret-vs-Mosaic is resolved ONCE by the kernel registry (platform probe
cached at first use — not re-evaluated per call at trace time).  Backend
selection (pallas vs the jnp refs) lives in
``repro.kernels.registry.get_kernels``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import registry


def lowrank_apply(u: jnp.ndarray, coeffs: jnp.ndarray, base,
                  g: jnp.ndarray) -> jnp.ndarray:
    return registry.get_kernels("pallas").lowrank_apply(u, coeffs, base, g)


def batched_lowrank_apply(u: jnp.ndarray, coeffs: jnp.ndarray, base,
                          g: jnp.ndarray) -> jnp.ndarray:
    """Pool-stack apply (leading N on every operand), grid-over-N."""
    return registry.get_kernels("pallas").batched_lowrank_apply(
        u, coeffs, base, g)
