"""Jitted public wrappers for the fused low-rank preconditioner apply.

Interpret-vs-Mosaic is resolved ONCE by the kernel registry (platform probe
cached at first use — not re-evaluated per call at trace time).  Backend
selection (pallas vs the jnp refs) lives in
``repro.kernels.registry.get_kernels``.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import autotune, registry


def lowrank_apply(u: jnp.ndarray, coeffs: jnp.ndarray, base,
                  g: jnp.ndarray) -> jnp.ndarray:
    return registry.get_kernels("pallas").lowrank_apply(u, coeffs, base, g)


def batched_lowrank_apply(u: jnp.ndarray, coeffs: jnp.ndarray, base,
                          g: jnp.ndarray, *,
                          config: Optional[autotune.TileConfig] = None
                          ) -> jnp.ndarray:
    """Pool-stack apply (leading N on every operand), grid-over-N.

    ``config`` pins an explicit TileConfig; omitted, the registry resolves
    one per shape from the tune cache (default tiles on a miss) — no call
    site hardcodes ``bn_stack`` anymore.
    """
    return registry.get_kernels("pallas").batched_lowrank_apply(
        u, coeffs, base, g, config=config)


def batched_lowrank_apply_quantized(values: jnp.ndarray, scale: jnp.ndarray,
                                    coeffs: jnp.ndarray, base,
                                    g: jnp.ndarray, *,
                                    config: Optional[autotune.TileConfig]
                                    = None) -> jnp.ndarray:
    """Quantized-storage apply: int8 values + per-block scale consumed
    directly (scale^2 folded into coeffs); see kernels/registry.py."""
    return registry.get_kernels("pallas").batched_lowrank_apply_quantized(
        values, scale, coeffs, base, g, config=config)


def batched_project_quantize(vq: jnp.ndarray, w_top: jnp.ndarray,
                             a: jnp.ndarray, w_bot: jnp.ndarray, *,
                             config: Optional[autotune.TileConfig] = None):
    """Fused FD write-back epilogue -> (values int8, scale f32); see
    kernels/lowrank/kernel.py."""
    return registry.get_kernels("pallas").batched_project_quantize(
        vq, w_top, a, w_bot, config=config)
