"""Pure-jnp oracles for the fused low-rank + diagonal apply (also the "xla"
backend entries).

``batched_lowrank_apply_ref`` mirrors ``jax.vmap(lowrank_apply_ref)``
primitive-for-primitive (batched dot_generals, broadcast scale) so the
pooled engine's XLA path stays bitwise-identical to the per-leaf vmap
dispatch it replaced.
"""
import jax
import jax.numpy as jnp


def lowrank_apply_ref(u: jnp.ndarray, coeffs: jnp.ndarray, base,
                      g: jnp.ndarray) -> jnp.ndarray:
    proj = u.T @ g
    return base * g + u @ (coeffs[:, None] * proj)


def batched_lowrank_apply_ref(u: jnp.ndarray, coeffs: jnp.ndarray, base,
                              g: jnp.ndarray) -> jnp.ndarray:
    """Per-pool-block apply: u (N, d, ell), coeffs (N, ell), base (N,),
    g (N, d, n) -> (N, d, n)."""
    proj = jax.lax.dot_general(u, g, (((1,), (1,)), ((0,), (0,))))
    scaled = coeffs[:, :, None] * proj
    expand = jax.lax.dot_general(u, scaled, (((2,), (1,)), ((0,), (0,))))
    return base[:, None, None] * g + expand


def batched_lowrank_apply_quantized_ref(values: jnp.ndarray,
                                        scale: jnp.ndarray,
                                        coeffs: jnp.ndarray, base,
                                        g: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the quantized-eigenvector apply: the per-block scale of
    the int8 factor commutes out of ``U diag(c) U^T`` as ``scale^2``, so
    the apply runs on the raw int8 values (upcast only) with the scale
    folded into the coefficients — the same algebra the pallas path uses.

    values (N, d, ell) int8, scale (N, 1, 1) f32, coeffs (N, ell),
    base (N,), g (N, d, n)."""
    s2 = jnp.square(scale.reshape(scale.shape[0], 1).astype(jnp.float32))
    return batched_lowrank_apply_ref(values.astype(jnp.float32),
                                     coeffs * s2, base, g)


def batched_project_quantize_ref(vq: jnp.ndarray, w_top: jnp.ndarray,
                                 a: jnp.ndarray, w_bot: jnp.ndarray
                                 ) -> tuple:
    """Oracle for the fused FD write-back epilogue: project the new factor
    and re-quantize per block (round-to-nearest, same rule as
    core/quantize.quantize_stack with no key)."""
    from repro.core import quantize
    un = jnp.matmul(vq.astype(jnp.float32), w_top) \
        + jnp.matmul(a.astype(jnp.float32), w_bot)
    qp = quantize.quantize_stack(un)
    return qp.values, qp.scale
