"""Pure-jnp oracle for the fused low-rank + diagonal apply."""
import jax.numpy as jnp


def lowrank_apply_ref(u: jnp.ndarray, coeffs: jnp.ndarray, base,
                      g: jnp.ndarray) -> jnp.ndarray:
    proj = u.T @ g
    return base * g + u @ (coeffs[:, None] * proj)
