"""Pure-jnp oracles for the fused low-rank + diagonal apply (also the "xla"
backend entries).

``batched_lowrank_apply_ref`` mirrors ``jax.vmap(lowrank_apply_ref)``
primitive-for-primitive (batched dot_generals, broadcast scale) so the
pooled engine's XLA path stays bitwise-identical to the per-leaf vmap
dispatch it replaced.
"""
import jax
import jax.numpy as jnp


def lowrank_apply_ref(u: jnp.ndarray, coeffs: jnp.ndarray, base,
                      g: jnp.ndarray) -> jnp.ndarray:
    proj = u.T @ g
    return base * g + u @ (coeffs[:, None] * proj)


def batched_lowrank_apply_ref(u: jnp.ndarray, coeffs: jnp.ndarray, base,
                              g: jnp.ndarray) -> jnp.ndarray:
    """Per-pool-block apply: u (N, d, ell), coeffs (N, ell), base (N,),
    g (N, d, n) -> (N, d, n)."""
    proj = jax.lax.dot_general(u, g, (((1,), (1,)), ((0,), (0,))))
    scaled = coeffs[:, :, None] * proj
    expand = jax.lax.dot_general(u, scaled, (((2,), (1,)), ((0,), (0,))))
    return base[:, None, None] * g + expand
