"""Backend-dispatch layer for the optimizer kernel set.

One resolution point replaces the per-call ``_on_tpu()`` checks that used to
live in every ``kernels/*/ops.py``: the platform is probed exactly once
(module-level LRU cache), the resulting ``KernelSet`` is interned per
(resolved backend, tune-cache snapshot), and everything downstream — the
pooled engine (core/api.py), Sketchy, Shampoo, the benchmarks — receives
the same frozen set of callables.

Backends
  ``"pallas"``  Pallas kernels (kernels/gram, kernels/lowrank).  Compiled to
                Mosaic on TPU; interpret-mode elsewhere (same kernel body,
                bit-for-bit the tiled accumulation order).
  ``"xla"``     Pure-jnp batched expressions (the ``ref.py`` oracles).  These
                are written to lower to exactly the primitives ``jax.vmap``
                of the single-block references produces, so the pooled
                engine's synchronized schedule stays bitwise-pinned to
                tests/reference_impls.py.
  ``"auto"``    ``pallas`` on TPU, ``xla`` otherwise.  The
                ``REPRO_KERNEL_BACKEND`` environment variable overrides the
                platform default (benchmarks/CI force either path without
                touching configs); explicit ``"pallas"``/``"xla"`` requests
                always win over the environment.

``KernelSet`` carries both the single-block entry points (direct FD calls,
OCO learners, the per-leaf fallback engine) and the batched grid-over-N
entry points the pooled ``(N, bs_m, bs_n)`` stacks dispatch to.  Every
batched entry accepts an optional ``config=`` TileConfig; when omitted, the
pallas entries resolve one per operand shape through
``kernels/autotune.get_config`` at *trace* time (a tuned run bakes in
different static tile args at zero per-step cost), and the xla entries
ignore it (jnp expressions have no tiles).  The resolved tune-cache
snapshot is part of the interning key, so reloading a cache
(``autotune.reload`` / ``tune_into_cache``) yields a fresh KernelSet while
identical cache state keeps returning the identical object.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune

BACKENDS = ("auto", "xla", "pallas")
ENV_VAR = "REPRO_KERNEL_BACKEND"


class KernelSet(NamedTuple):
    """The injectable kernel surface of the optimizer hot path.

    gram(a):                      (d, k)    -> (k, k)     C = A^T A, f32
    batched_gram(a):              (N, d, k) -> (N, k, k)  one gram per block
    lowrank_apply(u, c, b, g):    (d, ell), (ell,), (), (d, n) -> (d, n)
    batched_lowrank_apply(...):   leading N on every operand

    Fused quantized entries (int8 pool storage; see core/quantize.py):

    batched_gram_mixed(vq, colw, a):
        (N, d, k) int8, (N, k) f32, (N, d, r) f32 -> (N, k+r, k+r) f32 —
        the FD refresh Gram with the int8 eigenvector stack dequantized
        in-registers (never materialized as f32 in HBM).
    batched_lowrank_apply_quantized(values, scale, coeffs, base, g):
        the low-rank apply consuming the QuantizedPool storage directly —
        the per-block scale commutes out of ``U diag(c) U^T`` as
        ``scale^2`` and is folded into ``coeffs``.
    batched_project_quantize(vq, w_top, a, w_bot):
        fused FD write-back: project the refreshed eigenvectors and
        re-quantize them in one kernel -> (values int8, scale f32).

    ``tuned`` is the autotune snapshot this set was interned against —
    ``()``-like sentinel of the cache content, useful for determinism
    checks (same cache file => equal ``tuned``).
    """
    backend: str
    gram: Callable
    batched_gram: Callable
    lowrank_apply: Callable
    batched_lowrank_apply: Callable
    batched_gram_mixed: Callable
    batched_lowrank_apply_quantized: Callable
    batched_project_quantize: Callable
    tuned: tuple


@functools.lru_cache(maxsize=None)
def on_tpu() -> bool:
    """Platform probe, evaluated once per process (not per trace)."""
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    """True when Pallas kernels must run interpreted (non-TPU hosts)."""
    return not on_tpu()


def resolve_backend(backend: str = "auto") -> str:
    """``auto | xla | pallas`` -> concrete ``xla | pallas``.

    ``auto`` honors ``REPRO_KERNEL_BACKEND`` before falling back to the
    platform default; explicit requests bypass the environment.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS}")
    if backend != "auto":
        return backend
    env = os.environ.get(ENV_VAR, "")
    if env:
        if env not in ("xla", "pallas"):
            raise ValueError(
                f"{ENV_VAR}={env!r} is not a concrete backend; "
                "expected 'xla' or 'pallas'")
        return env
    return "pallas" if on_tpu() else "xla"


def get_kernels(backend: str = "auto") -> KernelSet:
    """Resolve ``backend`` and return the interned KernelSet for it.

    Identical requests against identical tune-cache state return the
    identical object (``lru_cache`` on the resolved name + autotune
    snapshot), so frozen-dataclass preconditioners holding a KernelSet
    stay hashable/equal across transform rebuilds — and a cache reload
    (new snapshot) produces a *new* set whose entries re-resolve configs.
    """
    return _kernel_set(resolve_backend(backend), autotune.snapshot())


def _fold_quantized_apply(batched_apply: Callable) -> Callable:
    """Quantized-storage apply from the plain batched apply: the per-block
    scale of the int8 factor commutes out of ``U diag(c) U^T`` as
    ``scale^2``, so the existing kernel consumes the raw int8 values (its
    in-kernel upcast IS the dequantize) with the scale folded into the
    coefficients — no f32 factor stack is ever materialized."""
    def apply_quantized(values, scale, coeffs, base, g,
                        config: Optional[Any] = None):
        s2 = jnp.square(
            scale.reshape(scale.shape[0], 1).astype(jnp.float32))
        return batched_apply(values, coeffs * s2, base, g, config=config)
    return apply_quantized


@functools.lru_cache(maxsize=None)
def _kernel_set(resolved: str, tuned: tuple) -> KernelSet:
    # imports deferred so merely importing the registry (e.g. for
    # resolve_backend validation in EngineConfig) stays cheap
    from repro.kernels.gram import kernel as gram_kernel
    from repro.kernels.gram import ref as gram_ref
    from repro.kernels.lowrank import kernel as lowrank_kernel
    from repro.kernels.lowrank import ref as lowrank_ref

    if resolved == "pallas":
        interp = interpret_mode()

        def batched_gram(a, config: Optional[Any] = None):
            c = config if config is not None else autotune.get_config(
                "batched_gram", tuple(a.shape), a.dtype)
            return gram_kernel.batched_gram_pallas(
                a, bk=c.bk, bd=c.bd, bn_stack=c.bn_stack, interpret=interp)

        def batched_gram_mixed(vq, colw, a, config: Optional[Any] = None):
            N, d, k = vq.shape
            c = config if config is not None else autotune.get_config(
                "batched_gram_mixed", (N, d, k, a.shape[-1]), vq.dtype)
            return gram_kernel.batched_gram_mixed_pallas(
                vq, colw, a, bd=c.bd, bn_stack=c.bn_stack, interpret=interp)

        def batched_lowrank_apply(u, coeffs, base, g,
                                  config: Optional[Any] = None):
            N, d, ell = u.shape
            c = config if config is not None else autotune.get_config(
                "batched_lowrank_apply", (N, d, ell, g.shape[-1]), u.dtype)
            return lowrank_kernel.batched_lowrank_apply_pallas(
                u, coeffs, base, g, bn=c.bn, bn_stack=c.bn_stack,
                interpret=interp)

        def batched_project_quantize(vq, w_top, a, w_bot,
                                     config: Optional[Any] = None):
            N, d, k = vq.shape
            c = config if config is not None else autotune.get_config(
                "batched_project_quantize",
                (N, d, k, a.shape[-1], w_top.shape[-1]), vq.dtype)
            return lowrank_kernel.batched_project_quantize_pallas(
                vq, w_top, a, w_bot, bn_stack=c.bn_stack, interpret=interp)

        return KernelSet(
            backend="pallas",
            gram=functools.partial(gram_kernel.gram_pallas,
                                   interpret=interp),
            batched_gram=batched_gram,
            lowrank_apply=functools.partial(
                lowrank_kernel.lowrank_apply_pallas, interpret=interp),
            batched_lowrank_apply=batched_lowrank_apply,
            batched_gram_mixed=batched_gram_mixed,
            batched_lowrank_apply_quantized=_fold_quantized_apply(
                batched_lowrank_apply),
            batched_project_quantize=batched_project_quantize,
            tuned=tuned,
        )
    if resolved != "xla":
        raise ValueError(f"unresolved backend {resolved!r}")

    # jnp expressions have no tile parameters: accept and ignore ``config``
    # so call sites stay backend-agnostic
    def xla_batched_gram(a, config: Optional[Any] = None):
        return gram_ref.batched_gram_ref(a)

    def xla_batched_gram_mixed(vq, colw, a, config: Optional[Any] = None):
        return gram_ref.batched_gram_mixed_ref(vq, colw, a)

    def xla_batched_lowrank_apply(u, coeffs, base, g,
                                  config: Optional[Any] = None):
        return lowrank_ref.batched_lowrank_apply_ref(u, coeffs, base, g)

    def xla_batched_lowrank_apply_quantized(values, scale, coeffs, base, g,
                                            config: Optional[Any] = None):
        return lowrank_ref.batched_lowrank_apply_quantized_ref(
            values, scale, coeffs, base, g)

    def xla_batched_project_quantize(vq, w_top, a, w_bot,
                                     config: Optional[Any] = None):
        return lowrank_ref.batched_project_quantize_ref(vq, w_top, a, w_bot)

    return KernelSet(
        backend="xla",
        gram=gram_ref.gram_ref,
        batched_gram=xla_batched_gram,
        lowrank_apply=lowrank_ref.lowrank_apply_ref,
        batched_lowrank_apply=xla_batched_lowrank_apply,
        batched_gram_mixed=xla_batched_gram_mixed,
        batched_lowrank_apply_quantized=xla_batched_lowrank_apply_quantized,
        batched_project_quantize=xla_batched_project_quantize,
        tuned=tuned,
    )
