"""Backend-dispatch layer for the optimizer kernel set.

One resolution point replaces the per-call ``_on_tpu()`` checks that used to
live in every ``kernels/*/ops.py``: the platform is probed exactly once
(module-level LRU cache), the resulting ``KernelSet`` is interned per
resolved backend, and everything downstream — the pooled engine
(core/api.py), Sketchy, Shampoo, the benchmarks — receives the same frozen
set of callables.

Backends
  ``"pallas"``  Pallas kernels (kernels/gram, kernels/lowrank).  Compiled to
                Mosaic on TPU; interpret-mode elsewhere (same kernel body,
                bit-for-bit the tiled accumulation order).
  ``"xla"``     Pure-jnp batched expressions (the ``ref.py`` oracles).  These
                are written to lower to exactly the primitives ``jax.vmap``
                of the single-block references produces, so the pooled
                engine's synchronized schedule stays bitwise-pinned to
                tests/reference_impls.py.
  ``"auto"``    ``pallas`` on TPU, ``xla`` otherwise.  The
                ``REPRO_KERNEL_BACKEND`` environment variable overrides the
                platform default (benchmarks/CI force either path without
                touching configs); explicit ``"pallas"``/``"xla"`` requests
                always win over the environment.

``KernelSet`` carries both the single-block entry points (direct FD calls,
OCO learners, the per-leaf fallback engine) and the batched grid-over-N
entry points the pooled ``(N, bs_m, bs_n)`` stacks dispatch to.
"""
from __future__ import annotations

import functools
import os
from typing import Callable, NamedTuple

import jax

BACKENDS = ("auto", "xla", "pallas")
ENV_VAR = "REPRO_KERNEL_BACKEND"


class KernelSet(NamedTuple):
    """The injectable kernel surface of the optimizer hot path.

    gram(a):                      (d, k)    -> (k, k)     C = A^T A, f32
    batched_gram(a):              (N, d, k) -> (N, k, k)  one gram per block
    lowrank_apply(u, c, b, g):    (d, ell), (ell,), (), (d, n) -> (d, n)
    batched_lowrank_apply(...):   leading N on every operand
    """
    backend: str
    gram: Callable
    batched_gram: Callable
    lowrank_apply: Callable
    batched_lowrank_apply: Callable


@functools.lru_cache(maxsize=None)
def on_tpu() -> bool:
    """Platform probe, evaluated once per process (not per trace)."""
    return jax.default_backend() == "tpu"


def interpret_mode() -> bool:
    """True when Pallas kernels must run interpreted (non-TPU hosts)."""
    return not on_tpu()


def resolve_backend(backend: str = "auto") -> str:
    """``auto | xla | pallas`` -> concrete ``xla | pallas``.

    ``auto`` honors ``REPRO_KERNEL_BACKEND`` before falling back to the
    platform default; explicit requests bypass the environment.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS}")
    if backend != "auto":
        return backend
    env = os.environ.get(ENV_VAR, "")
    if env:
        if env not in ("xla", "pallas"):
            raise ValueError(
                f"{ENV_VAR}={env!r} is not a concrete backend; "
                "expected 'xla' or 'pallas'")
        return env
    return "pallas" if on_tpu() else "xla"


def get_kernels(backend: str = "auto") -> KernelSet:
    """Resolve ``backend`` and return the interned KernelSet for it.

    Identical requests return the identical object (``lru_cache`` on the
    resolved name), so frozen-dataclass preconditioners holding a KernelSet
    stay hashable/equal across transform rebuilds.
    """
    return _kernel_set(resolve_backend(backend))


@functools.lru_cache(maxsize=None)
def _kernel_set(resolved: str) -> KernelSet:
    # imports deferred so merely importing the registry (e.g. for
    # resolve_backend validation in EngineConfig) stays cheap
    from repro.kernels.gram import kernel as gram_kernel
    from repro.kernels.gram import ref as gram_ref
    from repro.kernels.lowrank import kernel as lowrank_kernel
    from repro.kernels.lowrank import ref as lowrank_ref

    if resolved == "pallas":
        interp = interpret_mode()
        return KernelSet(
            backend="pallas",
            gram=functools.partial(gram_kernel.gram_pallas,
                                   interpret=interp),
            batched_gram=functools.partial(gram_kernel.batched_gram_pallas,
                                           interpret=interp),
            lowrank_apply=functools.partial(
                lowrank_kernel.lowrank_apply_pallas, interpret=interp),
            batched_lowrank_apply=functools.partial(
                lowrank_kernel.batched_lowrank_apply_pallas,
                interpret=interp),
        )
    if resolved != "xla":
        raise ValueError(f"unresolved backend {resolved!r}")
    return KernelSet(
        backend="xla",
        gram=gram_ref.gram_ref,
        batched_gram=gram_ref.batched_gram_ref,
        lowrank_apply=lowrank_ref.lowrank_apply_ref,
        batched_lowrank_apply=lowrank_ref.batched_lowrank_apply_ref,
    )
