"""Pallas TPU kernel: Mamba2 SSD chunk scan (single head-group, n_groups=1).

The sequential inter-chunk recurrence becomes the innermost grid dimension;
the (H-tile, P, N) running state lives in VMEM scratch across chunk steps, so
HBM traffic per chunk is exactly read(u, dlog, B, C tiles) + write(y tile) —
the decay matrices L and the per-chunk states never hit HBM (the pure-jnp
path materializes both).

Grid: (batch, head_tiles, n_chunks) — chunks sequential ("arbitrary"), batch
and head tiles parallel. Head tiles keep the VMEM working set
(Q x P x N + Q x Q decay) bounded; P and N are MXU-lane sized (64/128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(u_ref, dlog_ref, b_ref, c_ref, y_ref, state_ref,
                *, Q: int, HT: int, P: int, N: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    u = u_ref[0].astype(jnp.float32)          # (Q, HT, P)
    dlog = dlog_ref[0].astype(jnp.float32)    # (Q, HT)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    A_cs = jnp.cumsum(dlog, axis=0)           # (Q, HT)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    causal = rows >= cols

    state = state_ref[...]                    # (HT, P, N)
    dec_q = jnp.exp(A_cs)                     # (Q, HT)
    y = jnp.zeros((Q, HT, P), jnp.float32)
    # per-head-in-tile loop: HT is small (<= 8); keeps everything 2-D/MXU
    for h in range(HT):
        dec = A_cs[:, None, h] - A_cs[None, :, h]      # (Q, Q)
        L = jnp.where(causal, jnp.exp(dec), 0.0)
        intra = jax.lax.dot_general(scores * L, u[:, h, :],
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        inter = jax.lax.dot_general(Cm * dec_q[:, h:h + 1], state[h].T,
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        y = y.at[:, h, :].set(intra + inter)
        dec_end = jnp.exp(A_cs[-1, h] - A_cs[:, h])    # (Q,)
        new_s = jax.lax.dot_general(u[:, h, :] * dec_end[:, None], Bm,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        state = state.at[h].set(jnp.exp(A_cs[-1, h]) * state[h] + new_s)

    state_ref[...] = state
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "head_tile", "interpret"))
def ssd_pallas(u: jnp.ndarray, dlog: jnp.ndarray, Bm: jnp.ndarray,
               Cm: jnp.ndarray, *, chunk: int = 128, head_tile: int = 4,
               interpret: bool = True) -> jnp.ndarray:
    """u: (B, S, H, P); dlog: (B, S, H); Bm/Cm: (B, S, N) -> y like u.
    S must be a multiple of ``chunk`` and H of ``head_tile`` (callers pad)."""
    B, S, H, P = u.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0 and H % head_tile == 0, (S, Q, H, head_tile)
    n_chunks = S // Q
    HT = head_tile
    n_ht = H // HT

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q, HT=HT, P=P, N=N),
        grid=(B, n_ht, n_chunks),
        in_specs=[
            pl.BlockSpec((1, Q, HT, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, HT), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, HT, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), u.dtype),
        scratch_shapes=[pltpu.VMEM((HT, P, N), jnp.float32)],
        interpret=interpret,
    )(u, dlog, Bm, Cm)
    return out
