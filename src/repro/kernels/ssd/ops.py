"""Jitted public wrapper for the SSD chunk-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd_scan(u: jnp.ndarray, dlog: jnp.ndarray, Bm: jnp.ndarray,
             Cm: jnp.ndarray, *, chunk: int = 128,
             head_tile: int = 4) -> jnp.ndarray:
    return ssd_pallas(u, dlog, Bm, Cm, chunk=chunk, head_tile=head_tile,
                      interpret=not _on_tpu())
