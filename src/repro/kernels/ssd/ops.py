"""Jitted public wrapper for the SSD chunk-scan kernel.

Interpret-vs-Mosaic comes from the kernel registry's cached platform probe —
resolved once per process, not re-evaluated per call at trace time.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.ssd.kernel import ssd_pallas


def ssd_scan(u: jnp.ndarray, dlog: jnp.ndarray, Bm: jnp.ndarray,
             Cm: jnp.ndarray, *, chunk: int = 128,
             head_tile: int = 4) -> jnp.ndarray:
    return ssd_pallas(u, dlog, Bm, Cm, chunk=chunk, head_tile=head_tile,
                      interpret=registry.interpret_mode())
