"""Pure-jnp oracle for the SSD chunk scan: the model's own chunked
implementation (models/ssm.py), which is itself validated against decode."""
import jax.numpy as jnp

from repro.models.ssm import ssd


def ssd_ref(u: jnp.ndarray, dlog: jnp.ndarray, Bm: jnp.ndarray,
            Cm: jnp.ndarray, chunk: int = 128) -> jnp.ndarray:
    return ssd(u.astype(jnp.float32), dlog.astype(jnp.float32),
               Bm.astype(jnp.float32), Cm.astype(jnp.float32),
               chunk, unroll=True).astype(u.dtype)
