import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- the two lines above MUST run before any jax-importing module ---------
import argparse  # noqa: E402
import json      # noqa: E402
import sys       # noqa: E402

if "--devices" in sys.argv:  # tests shrink the fake-device pool
    _i = sys.argv.index("--devices")
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={sys.argv[_i + 1]}")

import jax  # noqa: E402


def main() -> None:
    p = argparse.ArgumentParser(description="Multi-pod dry-run driver")
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True, choices=[
        "train_4k", "prefill_32k", "decode_32k", "long_500k"])
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--optimizer", default="sketchy",
                   choices=["sketchy", "shampoo", "adam"])
    p.add_argument("--devices", type=int, default=512,
                   help="fake host device count (tests)")
    p.add_argument("--mesh", default=None,
                   help="override mesh e.g. '2x4:data,model'")
    p.add_argument("--skip-probes", action="store_true")
    p.add_argument("--smoke", action="store_true",
                   help="reduced config + tiny shape (tests)")
    p.add_argument("--skip-full", action="store_true")
    p.add_argument("--rules", default=None,
                   help="JSON logical-rule overrides (perf experiments)")
    p.add_argument("--opt-overrides", default=None,
                   help="JSON OptimizerConfig overrides")
    p.add_argument("--model-overrides", default=None,
                   help="JSON ModelConfig overrides (perf experiments)")
    p.add_argument("--microbatches", type=int, default=None,
                   help="gradient-accumulation microbatches (train cells)")
    p.add_argument("--out", default=None, help="write report JSON here")
    args = p.parse_args()

    from repro.launch.dryrun_lib import run_cell
    from repro.launch.mesh import make_mesh

    mesh = None
    if args.mesh:
        shape_s, axes_s = args.mesh.split(":")
        mesh = make_mesh(tuple(int(x) for x in shape_s.split("x")),
                         tuple(axes_s.split(",")))

    rule_overrides = json.loads(args.rules) if args.rules else None
    if rule_overrides:
        rule_overrides = {k: tuple(v) if isinstance(v, list) else v
                          for k, v in rule_overrides.items()}
    opt_overrides = json.loads(args.opt_overrides) if args.opt_overrides else None

    report = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                      optimizer=args.optimizer, mesh=mesh,
                      skip_probes=args.skip_probes, skip_full=args.skip_full,
                      rule_overrides=rule_overrides,
                      opt_overrides=opt_overrides,
                      model_overrides=(json.loads(args.model_overrides)
                                       if args.model_overrides else None),
                      microbatches=args.microbatches, smoke=args.smoke)
    text = json.dumps(report, indent=2, default=float)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text)


if __name__ == "__main__":
    main()
