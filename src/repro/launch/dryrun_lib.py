"""Dry-run lowering library (no XLA_FLAGS side effects — see dryrun.py CLI).

Per (arch x shape x mesh) cell:
  1. FULL lowering — production scan-over-layers graph; ``.lower().compile()``
     must succeed; provides ``memory_analysis()`` (fits-per-device proof) and
     the collective schedule.
  2. PROBE lowerings — tiny unrolled configs whose costs are affine in the
     per-block-type counts; solved and extrapolated to the full depth for
     trip-count-exact flops / bytes / collective bytes (DESIGN.md §4).
  3. Roofline terms + MODEL_FLOPS ratio.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.core.factory import OptimizerConfig, make_optimizer
from repro.launch import roofline
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.sharding import rules as rules_lib
from repro.train import trainer as trainer_lib

# per-shape logical-rule overrides (baseline policy)
RULE_OVERRIDES = {
    "long_500k": {"batch": None, "kv_seq": ("data", "model")},
}


def _repl(rules):
    return NamedSharding(rules.mesh, P())


def batch_shardings(specs: dict, rules: rules_lib.MeshRules) -> dict:
    out = {}
    for k, v in specs.items():
        if k == "positions":            # (3, B, S)
            sh = rules.sharding(None, "batch", None)
        else:
            axes = ["batch"] + [None] * (v.ndim - 1)
            sh = rules.sharding(*axes)
        out[k] = rules_lib.enforce_divisible(sh, v.shape)
    return out


def cache_shardings(cfg: ModelConfig, struct: dict,
                    rules: rules_lib.MeshRules) -> dict:
    out = {}
    for k, v in struct.items():
        if k in ("k", "v"):             # (L, B, Smax, KV, hd)
            sh = rules.sharding(None, "batch", "kv_seq", None, None)
        elif k == "ssm":                # (L, B, H, P, N)
            sh = rules.sharding(None, "batch", "heads", None, None)
        elif k == "conv":               # (L, B, W-1, conv_dim)
            sh = rules.sharding(None, "batch", None, "tensor")
        else:
            sh = _repl(rules)
        out[k] = rules_lib.enforce_divisible(sh, v.shape)
    return out


# ---------------------------------------------------------------------------


def lower_cell(cfg: ModelConfig, shape: registry.ShapeCfg,
               rules: rules_lib.MeshRules, opt_cfg: OptimizerConfig, *,
               unroll: bool, donate: bool = True,
               microbatches: Optional[int] = None):
    """Build + lower one cell. Returns jax ``Lowered``."""
    pstruct = model_lib.param_struct(cfg)
    psh = rules_lib.tree_param_shardings(pstruct, rules)

    if shape.kind == "train":
        tx = make_optimizer(opt_cfg)
        ostruct = jax.eval_shape(tx.init, pstruct)
        osh = trainer_lib.train_state_shardings(ostruct, pstruct, rules)
        bstruct = registry.input_specs(cfg, shape)
        bsh = batch_shardings(bstruct, rules)
        # donate=False: we re-jit below with explicit shardings (and our own
        # donate_argnums) — the raw callable is what lower() needs
        step = trainer_lib.make_train_step(cfg, tx, unroll=unroll,
                                           microbatches=microbatches,
                                           donate=False)
        jf = jax.jit(step, in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None),
                     donate_argnums=(0, 1) if donate else ())
        return jf.lower(pstruct, ostruct, bstruct)

    if shape.kind == "prefill":
        bstruct = registry.input_specs(cfg, shape)
        bsh = batch_shardings(bstruct, rules)

        def fwd(params, batch):
            return model_lib.forward(cfg, params, batch, unroll=unroll)

        jf = jax.jit(fwd, in_shardings=(psh, bsh))
        return jf.lower(pstruct, bstruct)

    if shape.kind == "decode":
        bstruct = registry.input_specs(cfg, shape)
        bsh = batch_shardings(bstruct, rules)
        cstruct = cache_lib.cache_struct(cfg, shape.global_batch, shape.seq_len)
        csh = cache_shardings(cfg, cstruct, rules)
        pos = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, cache, batch, pos):
            return cache_lib.decode_step(cfg, params, cache, batch, pos,
                                         unroll=unroll)

        jf = jax.jit(serve_step,
                     in_shardings=(psh, csh, bsh, _repl(rules)),
                     out_shardings=(None, csh),
                     donate_argnums=(1,) if donate else ())
        return jf.lower(pstruct, cstruct, bstruct, pos)

    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Probe plans


def probe_plan(cfg: ModelConfig) -> Tuple[List[Tuple[dict, Tuple[int, ...]]],
                                          Tuple[int, ...]]:
    """[(config replacements, counts), ...], full_counts."""
    fam = cfg.family
    L = cfg.num_layers
    if fam in ("dense", "vlm", "audio", "ssm"):
        probes = [({"num_layers": 1}, (1,)), ({"num_layers": 2}, (2,))]
        return probes, (L,)
    if fam == "moe":
        fd = cfg.first_dense_layers
        probes = [
            ({"num_layers": 1, "first_dense_layers": 0}, (0, 1)),
            ({"num_layers": 2, "first_dense_layers": 1}, (1, 1)),
            ({"num_layers": 3, "first_dense_layers": 1}, (1, 2)),
        ]
        return probes, (fd, L - fd)
    if fam == "hybrid":
        # every probe keeps >= 1 shared-attention site (decode caches slice
        # into the sites-only cache, which must be non-empty)
        n_sites = len(cfg.shared_attn_layers())
        probes = [
            ({"num_layers": 1, "attn_every": 1}, (1, 1)),
            ({"num_layers": 2, "attn_every": 1}, (2, 2)),
            ({"num_layers": 2, "attn_every": 2}, (2, 1)),
        ]
        return probes, (L, n_sites)
    raise ValueError(fam)


def probe_costs(cfg: ModelConfig, shape: registry.ShapeCfg,
                rules: rules_lib.MeshRules, opt_cfg: OptimizerConfig,
                microbatches: Optional[int] = None) -> roofline.ProbeCost:
    probes, full_counts = probe_plan(cfg)
    counts, costs = [], []
    for repl, cnt in probes:
        pcfg = dataclasses.replace(cfg, **repl)
        lowered = lower_cell(pcfg, shape, rules, opt_cfg, unroll=True,
                             donate=False, microbatches=microbatches)
        compiled = lowered.compile()
        costs.append(roofline.cost_of(compiled))
        counts.append(cnt)
    return roofline.solve_affine(counts, costs, full_counts)


# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             optimizer: str = "sketchy", mesh=None,
             skip_probes: bool = False, skip_full: bool = False,
             rule_overrides: Optional[dict] = None,
             opt_overrides: Optional[dict] = None,
             model_overrides: Optional[dict] = None,
             microbatches: Optional[int] = None,
             smoke: bool = False) -> Dict:
    """Execute one dry-run cell; returns the report dict.
    ``smoke``: reduced config + tiny shape (integration tests)."""
    from repro.launch.mesh import make_production_mesh

    cfg = registry.get_reduced(arch) if smoke else registry.get_config(arch)
    if model_overrides:
        cfg = dataclasses.replace(cfg, **model_overrides)
    shape = registry.SHAPES[shape_name]
    if smoke:
        shape = registry.ShapeCfg(shape.name, seq_len=64, global_batch=8,
                                  kind=shape.kind)
    if shape_name == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape_name,
                "skipped": "full-attention arch; long_500k requires "
                           "sub-quadratic attention (DESIGN.md §5)"}

    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    overrides = dict(RULE_OVERRIDES.get(shape_name, {}))
    overrides.update(rule_overrides or {})

    opt_kwargs = dict(name=optimizer, rank=256 if not smoke else 8,
                      block_size=1024 if not smoke else 32,
                      update_every=10, total_steps=10000)
    opt_kwargs.update(opt_overrides or {})
    opt_cfg = OptimizerConfig(**opt_kwargs)

    report: Dict = {"arch": arch, "shape": shape_name,
                    "mesh": "x".join(map(str, mesh.devices.shape)),
                    "axes": list(mesh.axis_names), "chips": int(n_chips),
                    "optimizer": optimizer, "kind": shape.kind}

    with rules_lib.use_mesh(mesh, overrides) as rules:
        if not skip_full:
            t0 = time.time()
            lowered = lower_cell(cfg, shape, rules, opt_cfg, unroll=False,
                                 microbatches=microbatches)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            mem = compiled.memory_analysis()
            report["full"] = {
                "lower_s": round(t1 - t0, 2),
                "compile_s": round(t2 - t1, 2),
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
                "peak_bytes_per_device": int(
                    getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)
                    - getattr(mem, "alias_size_in_bytes", 0)),
                "scan_cost_raw": dataclasses.asdict(
                    roofline.cost_of(compiled)),
            }

        if not skip_probes:
            t0 = time.time()
            cost = probe_costs(cfg, shape, rules, opt_cfg, microbatches)
            report["probe_s"] = round(time.time() - t0, 2)
            terms = roofline.roofline_terms(cost)
            report["cost"] = {"flops_per_device": cost.flops,
                              "bytes_per_device": cost.bytes_accessed,
                              "collective_bytes_per_device": cost.coll}
            report["roofline"] = terms

            # MODEL_FLOPS vs compiled-flops ratio (useful-compute fraction)
            tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                           else 1)
            mf = cfg.model_flops_per_token(
                shape.seq_len, training=(shape.kind == "train"),
                decode=(shape.kind == "decode")) * tokens
            report["model_flops_total"] = mf
            hlo_total = cost.flops * n_chips
            report["hlo_flops_total"] = hlo_total
            report["useful_flops_ratio"] = (mf / hlo_total) if hlo_total else 0.0
            # roofline fraction: ideal time on dominant term vs bound
            ideal = mf / (n_chips * roofline.PEAK_FLOPS)
            report["ideal_compute_s"] = ideal
            report["roofline_fraction"] = (
                ideal / terms["bound_s"] if terms["bound_s"] else 0.0)

    return report
