"""Dataclass-driven ``key=value,...`` CLI flag parsing.

Every structured launcher flag (``--rank-budget`` on launch/train.py;
``--traffic``/``--adapt``/``--monitor`` on launch/serve.py) is one compact
spec string parsed against a config dataclass: the dataclass's fields ARE
the schema (names + type hints), so flags never drift from the configs they
build.  Unknown keys fail with the same ``unknown key {k!r}; have [...]``
message everywhere.

    cfg = parse_kv_spec("total=64,every=2", RankBudget,
                        aliases={"every": "realloc_every"},
                        error=lambda m: p.error(f"--rank-budget: {m}"))
"""
from __future__ import annotations

import dataclasses
import typing
from typing import Callable, Dict, Optional, Type, TypeVar

T = TypeVar("T")


def _unwrap_optional(tp):
    if typing.get_origin(tp) is typing.Union:
        args = [a for a in typing.get_args(tp) if a is not type(None)]
        if len(args) == 1:
            return args[0]
    return tp


def _convert(raw: str, tp):
    tp = _unwrap_optional(tp)
    if tp is bool:
        low = raw.lower()
        if low in ("1", "true", "yes", "on"):
            return True
        if low in ("0", "false", "no", "off"):
            return False
        raise ValueError(f"not a bool: {raw!r}")
    if tp in (int, float, str):
        return tp(raw)
    return tp(raw)    # e.g. enums with a str constructor


def parse_kv_spec(spec: str, cls: Type[T], *,
                  aliases: Optional[Dict[str, str]] = None,
                  error: Optional[Callable[[str], None]] = None) -> T:
    """Parse ``"k=v,k=v"`` into dataclass ``cls``.

    ``aliases`` maps CLI spellings to field names (the CLI key replaces its
    target in the allowed set, keeping old flag vocabularies stable across
    dataclass renames).  ``error`` is called with the message on bad input
    (argparse's ``p.error`` — which raises SystemExit); by default a
    ValueError is raised.
    """
    aliases = aliases or {}

    def fail(msg: str):
        if error is not None:
            error(msg)        # argparse error() raises; belt-and-braces:
        raise ValueError(msg)

    hints = typing.get_type_hints(cls)
    field_names = {f.name for f in dataclasses.fields(cls)}
    # CLI vocabulary: aliased spellings replace their targets
    allowed = (field_names - set(aliases.values())) | set(aliases)

    kw = {}
    for tok in spec.split(","):
        if not tok.strip():
            continue
        k, sep, v = tok.partition("=")
        k, v = k.strip(), v.strip()
        if not sep:
            fail(f"expected key=value, got {tok.strip()!r}")
        if k not in allowed:
            fail(f"unknown key {k!r}; have {sorted(allowed)}")
        name = aliases.get(k, k)
        try:
            kw[name] = _convert(v, hints[name])
        except ValueError:
            fail(f"bad value for {k!r}: {v!r} "
                 f"(want {_unwrap_optional(hints[name]).__name__})")
    try:
        return cls(**kw)
    except (ValueError, TypeError) as e:   # dataclass __post_init__ checks
        fail(str(e))
