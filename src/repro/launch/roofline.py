"""Roofline analysis from compiled dry-run artifacts (DESIGN.md §4).

Because ``compiled.cost_analysis()`` counts loop bodies once (verified in
this container), production scan-over-layers lowerings undercount. We lower
small *probe* models — fully unrolled, 1-3 layers — whose cost is affine in
the per-block-type counts: C(n) = outer + sum_i n_i * block_i. Solving the
affine system from len(types)+1 probes and evaluating at the full layer
counts gives trip-count-exact totals for flops, bytes and collective bytes.

Collective bytes are parsed from the probes' *optimized* (post-SPMD)
``compiled.as_text()`` HLO — summing operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per assignment).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List, Sequence

import numpy as np

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s/link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out = {k: 0.0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for kind in COLLECTIVE_OPS:
            # match the op name right after the result shape, e.g.
            # "bf16[8,128]{1,0} all-gather(..."
            if re.search(r"\}?\s" + kind + r"(-start|-done)?\(", rhs):
                op = kind
                break
        if op is None:
            continue
        if f" {op}-done(" in rhs or rhs.startswith(f"{op}-done("):
            continue  # avoid double counting async pairs
        # operand shapes: inside the call parens
        call = rhs.split(op, 1)[1]
        total = 0
        for dt, dims in _SHAPE_RE.findall(call):
            total += _shape_bytes(dt, dims)
        if total == 0:
            # fall back to result shape (all-reduce: result == operand)
            for dt, dims in _SHAPE_RE.findall(rhs.split(op)[0]):
                total += _shape_bytes(dt, dims)
        out[op] += float(total)
    out["total"] = float(sum(out[k] for k in COLLECTIVE_OPS))
    return out


@dataclasses.dataclass
class ProbeCost:
    flops: float
    bytes_accessed: float
    coll: Dict[str, float]


def cost_of(compiled) -> ProbeCost:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ProbeCost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll=collective_bytes(compiled.as_text()),
    )


def solve_affine(probe_counts: Sequence[Sequence[int]],
                 probe_costs: Sequence[ProbeCost],
                 full_counts: Sequence[int]) -> ProbeCost:
    """C(n) = outer + n . blocks; evaluate at full_counts."""
    A = np.array([[1.0] + list(c) for c in probe_counts])
    full = np.array([1.0] + list(full_counts))

    def solve(vals):
        coef, *_ = np.linalg.lstsq(A, np.array(vals, dtype=np.float64),
                                   rcond=None)
        return float(max(full @ coef, 0.0))

    flops = solve([p.flops for p in probe_costs])
    byts = solve([p.bytes_accessed for p in probe_costs])
    keys = set()
    for p in probe_costs:
        keys |= set(p.coll)
    coll = {k: solve([p.coll.get(k, 0.0) for p in probe_costs]) for k in keys}
    return ProbeCost(flops=flops, bytes_accessed=byts, coll=coll)


def roofline_terms(cost: ProbeCost) -> Dict[str, float]:
    """Per-device seconds for each roofline term (SPMD ⇒ per-device HLO)."""
    t_compute = cost.flops / PEAK_FLOPS
    t_memory = cost.bytes_accessed / HBM_BW
    t_coll = cost.coll.get("total", 0.0) / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1])[0]
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_coll),
    }
