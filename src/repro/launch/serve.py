"""Batched-serving launcher (CPU-scale demo; 32k/500k decode via dryrun.py)."""
from __future__ import annotations

import argparse


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-lm-100m")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=64)
    p.add_argument("--new-tokens", type=int, default=12)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    import numpy as np
    import jax

    from repro.configs import registry
    from repro.models import model as model_lib
    from repro.serve.engine import Engine, Request

    cfg = registry.get_reduced(args.arch) if args.reduced \
        else registry.get_config(args.arch)
    if not cfg.embed_inputs or cfg.num_codebooks:
        raise SystemExit("serve demo supports token-input archs")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = Engine(cfg, params, max_seq=args.max_seq, batch=args.batch)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=(8,),
                                        dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.batch)]
    results = engine.generate(reqs)
    for i, r in enumerate(results):
        print(f"request {i}: prompt={list(map(int, reqs[i].prompt))} "
              f"-> {r.tokens}")


if __name__ == "__main__":
    main()
