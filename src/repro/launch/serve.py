"""Serving launcher: continuous batching + FD telemetry + online adaptation.

CPU-scale demo of the full serve loop (32k/500k decode lives in dryrun.py):

  # one-shot demo: submit a batch, drain, print tokens
  python -m repro.launch.serve --batch 4 --new-tokens 12

  # load-generator traffic + FD gradient monitor + S-AdaGrad adaptation
  python -m repro.launch.serve \\
      --traffic shape=step,rate=1.0,ticks=24,step_at=12 \\
      --monitor window=4,ell=8 --adapt lr=0.1,beta2=0.95

The structured flags are ``key=value,...`` specs parsed against the config
dataclasses themselves (launch/flags.py): ``--traffic`` -> TrafficConfig,
``--adapt`` -> AdaptConfig, ``--monitor`` -> MonitorConfig.  With traffic
enabled, each tick submits the generated arrivals, steps the engine, draws
a feedback batch, feeds its head gradient to the monitor, and runs one
adaptation step whenever the window policy says "adapt".
"""
from __future__ import annotations

import argparse


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-lm-100m")
    p.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                   default=True, help="use the registry's reduced config "
                   "(--no-reduced for the full arch)")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=64)
    p.add_argument("--new-tokens", type=int, default=12)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--traffic", default=None, metavar="K=V,...",
                   help="TrafficConfig spec, e.g. shape=step,rate=1,ticks=24")
    p.add_argument("--adapt", default=None, metavar="K=V,...",
                   help="AdaptConfig spec, e.g. lr=0.1,beta2=0.95,ell=8")
    p.add_argument("--monitor", default=None, metavar="K=V,...",
                   help="MonitorConfig spec, e.g. window=4,ell=8")
    args = p.parse_args()

    import numpy as np
    import jax

    from repro.configs import registry
    from repro.launch.flags import parse_kv_spec
    from repro.models import model as model_lib
    from repro.serve import (AdaptConfig, Engine, GradientMonitor,
                             LoadGenerator, MonitorConfig, OnlineAdapter,
                             Request, ServeConfig, TrafficConfig)

    cfg = registry.get_reduced(args.arch) if args.reduced \
        else registry.get_config(args.arch)
    if not cfg.embed_inputs or cfg.num_codebooks:
        p.error(f"serving supports token-input archs only; {args.arch!r} "
                f"has embed_inputs={cfg.embed_inputs} "
                f"num_codebooks={cfg.num_codebooks}")
    params = model_lib.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = Engine(cfg, params,
                    ServeConfig(batch=args.batch, max_seq=args.max_seq,
                                seed=args.seed))

    if args.traffic is None:
        # one-shot demo through the session API
        rng = np.random.default_rng(args.seed)
        handles = [engine.submit(Request(
            prompt=rng.integers(0, cfg.vocab_size, size=(8,),
                                dtype=np.int32),
            max_new_tokens=args.new_tokens)) for _ in range(args.batch)]
        engine.drain()
        for h in handles:
            print(f"request {h.id}: prompt={list(map(int, h.request.prompt))}"
                  f" -> {h.tokens}")
        return

    traffic = parse_kv_spec(args.traffic, TrafficConfig,
                            error=lambda m: p.error(f"--traffic: {m}"))
    gen = LoadGenerator(traffic, cfg.vocab_size)

    adapter = monitor = None
    if args.adapt is not None:
        adapter = OnlineAdapter(cfg, params, parse_kv_spec(
            args.adapt, AdaptConfig,
            error=lambda m: p.error(f"--adapt: {m}")))
    if args.monitor is not None:
        if adapter is None:
            adapter = OnlineAdapter(cfg, params)   # gradients for telemetry
        monitor = GradientMonitor(adapter.d, parse_kv_spec(
            args.monitor, MonitorConfig,
            error=lambda m: p.error(f"--monitor: {m}")))

    from repro.data.pipeline import DataConfig, SyntheticLM
    feedback = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=16, global_batch=4,
        seed=args.seed + 1))

    handles, adapt_steps = [], 0
    for tick in range(traffic.ticks):
        for req in gen.arrivals(tick):
            handles.append(engine.submit(req))
        engine.step()
        if adapter is not None:
            batch = feedback.batch(tick)
            loss, g = adapter.grad(params, batch)
            if monitor is None:
                run_adapt = True              # no policy: adapt every tick
            else:
                reading = monitor.observe(g)  # None mid-window
                run_adapt = reading is not None and reading.decision == "adapt"
            if run_adapt:
                params, loss = adapter.step(params, batch)
                engine.params = params        # serve the adapted weights
                adapt_steps += 1
    engine.drain()
    done = sorted(handles, key=lambda h: h.id)

    lat = [t1 - t0 for h in done for t0, t1 in
           zip(h.token_times, h.token_times[1:])]
    print(f"served {len(done)} requests, "
          f"{sum(len(h.tokens) for h in done)} tokens over "
          f"{engine.step_count} engine steps")
    if lat:
        print(f"inter-token latency p50={np.percentile(lat, 50)*1e3:.2f}ms "
              f"p99={np.percentile(lat, 99)*1e3:.2f}ms")
    if monitor is not None:
        for r in monitor.readings:
            print(r)
    if adapter is not None:
        print(f"adaptation steps: {adapt_steps} "
              f"(hyperparams: {adapter.hyperparams})")


if __name__ == "__main__":
    main()
