"""End-to-end training launcher (runnable at CPU scale; the production mesh
is exercised via dryrun.py).

Features: config/arch selection, synthetic data pipeline, optimizer choice
(sketchy/shampoo/adam), async atomic checkpointing + restart, straggler
monitor, optional int8 gradient compression, optional mesh.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-lm-100m")
    p.add_argument("--reduced", action="store_true",
                   help="use the reduced smoke config (CPU-friendly)")
    p.add_argument("--optimizer", default="sketchy",
                   choices=["sketchy", "shampoo", "adam"])
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--rank", type=int, default=64)
    p.add_argument("--rank-budget", default=None, metavar="SPEC",
                   help="sketch-rank budget (sketchy only; core/sketchy."
                        "RankBudget): comma-separated key=value pairs from "
                        "total,min_k,max_k,every,policy — e.g. "
                        "'total=2048,min_k=8,max_k=128,policy=rho_greedy'. "
                        "Memory stays at max_k capacity while active rank "
                        "migrates to high-rho blocks; omitted keys use the "
                        "RankBudget defaults and --rank is ignored")
    p.add_argument("--update-every", type=int, default=10)
    p.add_argument("--block-size", type=int, default=1024)
    p.add_argument("--kernel-backend", default="auto",
                   choices=["auto", "xla", "pallas"],
                   help="optimizer kernel path: grid-over-N Pallas batched "
                        "kernels vs pure-XLA refs (auto = pallas on TPU)")
    p.add_argument("--second-moment-dtype", default="fp32",
                   choices=["fp32", "bf16", "int8"],
                   help="storage dtype for pooled second-moment stacks "
                        "between steps (core/quantize.py): fp32 = bitwise "
                        "parity, bf16 = 2x smaller, int8 = per-block "
                        "quantized matrix factors (~4x); compute stays f32")
    p.add_argument("--quantized-epilogue", default="auto",
                   choices=["auto", "off", "on"],
                   help="fused int8 compute (core/api.py): with "
                        "--second-moment-dtype int8, auto fuses dequantize/"
                        "requantize into the pallas kernels (no f32 factor "
                        "stack at the pool boundary); off = always "
                        "dequantize at the boundary; on = force the fused "
                        "math on any backend (sketchy only)")
    p.add_argument("--refresh-schedule", default="synchronized",
                   choices=["synchronized", "staggered"],
                   help="refresh phasing over the pooled block stacks: "
                        "synchronized = all blocks every update-every steps "
                        "(eigh spike); staggered = ~N/update_every blocks "
                        "per step, same amortized cost, flat step time")
    p.add_argument("--refresh-mode", default="inline",
                   choices=["inline", "async"],
                   help="when the refresh lands (core/api.py): inline = "
                        "same step (parity default); async = launched at "
                        "step t into a double-buffered pending slot and "
                        "committed at t+1, so the eigh + butterfly merge "
                        "overlap with the next step's forward/backward")
    p.add_argument("--profile-annotations", action="store_true",
                   help="emit named_scope/TraceAnnotation spans around the "
                        "engine's update/refresh/precondition phases")
    p.add_argument("--stats-reduction", default="replicated",
                   choices=["replicated", "sharded"],
                   help="second-moment maintenance across data-parallel "
                        "shards (src/repro/distributed/): replicated = every "
                        "device maintains identical stats from mean grads; "
                        "sharded = local FD updates + log-depth butterfly "
                        "sketch merge over the data axis at refresh time "
                        "(sketchy only; needs > 1 device)")
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--checkpoint-every", type=int, default=50)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--metrics-out", default=None)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import registry
    from repro.core.factory import OptimizerConfig, make_optimizer
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import model as model_lib
    from repro.train import checkpoint as ckpt_lib
    from repro.train.elastic import StragglerMonitor
    from repro.train.trainer import make_train_step

    cfg = registry.get_reduced(args.arch) if args.reduced \
        else registry.get_config(args.arch)
    rank_budget = None
    if args.rank_budget:
        from repro.core.sketchy import RankBudget
        from repro.launch.flags import parse_kv_spec
        rank_budget = parse_kv_spec(
            args.rank_budget, RankBudget,
            aliases={"every": "realloc_every"},
            error=lambda m: p.error(f"--rank-budget: {m}"))
    opt_cfg = OptimizerConfig(
        name=args.optimizer, learning_rate=args.lr, total_steps=args.steps,
        rank=args.rank, rank_budget=rank_budget, block_size=args.block_size,
        update_every=args.update_every, weight_decay=1e-4,
        kernel_backend=args.kernel_backend,
        refresh_schedule=args.refresh_schedule,
        refresh_mode=args.refresh_mode,
        profile_annotations=args.profile_annotations,
        second_moment_dtype=args.second_moment_dtype,
        quantized_epilogue=args.quantized_epilogue,
        stats_reduction=args.stats_reduction)
    tx = make_optimizer(opt_cfg)

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        num_codebooks=cfg.num_codebooks,
        embed_dim=0 if cfg.embed_inputs else cfg.d_model))

    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(cfg, key)
    opt_state = tx.init(params)
    start_step = 0

    ckpt = None
    if args.checkpoint_dir:
        ckpt = ckpt_lib.AsyncCheckpointer(args.checkpoint_dir)
        if args.resume and ckpt_lib.latest_step(args.checkpoint_dir) is not None:
            (params, opt_state), start_step, extra = ckpt_lib.restore(
                args.checkpoint_dir, (params, opt_state))
            print(f"resumed from step {start_step}")

    dp_mesh = None
    if args.stats_reduction == "sharded":
        ndev = len(jax.devices())
        if ndev > 1 and args.batch % ndev == 0:
            dp_mesh = jax.make_mesh((ndev,), ("data",))
            print(f"sharded stats over data axis ({ndev} devices)")
        else:
            print(f"sharded stats requested but devices={ndev} "
                  f"batch={args.batch}; falling back to replicated")
    # make_train_step jits with params/opt_state donated; the async
    # checkpointer snapshots to host before the next step consumes them
    step_fn = make_train_step(cfg, tx, data_parallel_mesh=dp_mesh)
    monitor = StragglerMonitor()
    metrics_log = []

    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M optimizer={args.optimizer}")

    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        monitor.start()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = monitor.stop()
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            metrics_log.append({"step": step, "loss": loss, "time_s": dt})
        if ckpt and step and step % args.checkpoint_every == 0:
            ckpt.save(step, (params, opt_state))
    if ckpt:
        ckpt.save(args.steps, (params, opt_state))
        ckpt.wait()
    if monitor.flagged:
        print(f"straggler steps flagged: {monitor.flagged} "
              f"(median {monitor.median*1e3:.0f}ms)")
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump(metrics_log, f, indent=2)


if __name__ == "__main__":
    main()
