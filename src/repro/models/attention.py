"""Attention: GQA with q-chunked causal softmax (no S^2 materialization),
RoPE / M-RoPE / qk-norm / qkv-bias variants, KV-cache decode path.

Training/prefill attention iterates over query chunks; each chunk attends to
the full prefix with an online-safe fp32 softmax. ``unroll=True`` (probe mode,
DESIGN.md §4) replaces the lax.scan with a Python loop so
``compiled.cost_analysis()`` sees every chunk.

GQA is computed grouped — queries reshaped to (B, S, KV, G, hd) — so KV is
never repeated in memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rms_norm
from repro.sharding import rules as rules_lib
from repro.sharding.rules import axis_extent, constrain, shard_map

NEG_INF = -1e30


def attn_params_shape(cfg: ModelConfig) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    shapes = {
        "wq": (D, H * hd),
        "wk": (D, KV * hd),
        "wv": (D, KV * hd),
        "wo": (H * hd, D),
    }
    if cfg.qkv_bias:
        shapes.update({"bq": (H * hd,), "bk": (KV * hd,), "bv": (KV * hd,)})
    if cfg.qk_norm:
        shapes.update({"q_norm": (hd,), "k_norm": (hd,)})
    return shapes


def _project_qkv(cfg: ModelConfig, p: dict, x: jnp.ndarray, positions):
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "heads", None)
    v = constrain(v, "batch", "seq", "heads", None)
    return q, k, v


def _head_sharding_mode(KV: int, G: int, cq: int) -> str:
    """TP policy for attention internals.

    'heads' when the (padded-free) head dims divide the model axis;
    otherwise 'qchunk': shard the query-chunk dim instead (sequence-parallel
    softmax — always divisible since cq is a power of two). Non-divisible
    head sharding makes GSPMD all-gather the full fp32 logits
    (EXPERIMENTS.md §Perf)."""
    n = axis_extent("heads")
    if n == 1:
        return "none"
    if KV % n == 0:
        return "heads"
    if cq % n == 0:   # GQA with KV < model axis: shard query positions
        return "qchunk"
    return "none"


def _attend_math(q_chunk, k, v, q_start, kv_len=None,
                 logits_dtype=jnp.float32):
    """Pure attention math for one q chunk (no sharding annotations).

    ``q_start``/``kv_len`` are scalars for the aligned train/prefill path,
    or (B,) vectors for continuous-batching decode where every lane sits at
    its own position (serve/engine.py slot reuse).

    ``logits_dtype`` controls the MATERIALIZED logits dtype (HBM traffic in
    the jnp fallback); the row max is always tracked in f32 and subtracted
    before the cast, so bf16 only quantizes already-centered values."""
    B, cq, KV, G, hd = q_chunk.shape
    S = k.shape[1]
    scale = hd ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", q_chunk.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    k_pos = jnp.arange(S)
    if jnp.ndim(q_start):                       # per-lane decode positions
        q_pos = q_start[:, None] + jnp.arange(cq)          # (B, cq)
        mask = q_pos[:, :, None] >= k_pos[None, None, :]   # (B, cq, S)
        if kv_len is not None:
            mask = mask & (k_pos[None, None, :] < kv_len[:, None, None])
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    else:
        q_pos = q_start + jnp.arange(cq)
        mask = q_pos[:, None] >= k_pos[None, :]
        if kv_len is not None:
            mask = mask & (k_pos[None, :] < kv_len)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    if logits_dtype != jnp.float32:
        m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
        s = (s - m).astype(logits_dtype)
        p = jnp.exp(s)
        p = p / jnp.sum(p.astype(jnp.float32), axis=-1,
                        keepdims=True).astype(logits_dtype)
    else:
        p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)


def _chunk_attend(q_chunk: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  q_start, kv_len=None, logits_dtype="float32") -> jnp.ndarray:
    """q_chunk: (B, cq, KV, G, hd); k, v: (B, S, KV, hd). Causal vs absolute
    positions; kv_len masks cache tail when decoding.

    Sharding policy (EXPERIMENTS.md §Perf): when KV heads divide the model
    axis, annotate head sharding and let GSPMD place the softmax; otherwise
    shard QUERY POSITIONS explicitly with shard_map — forward and backward
    are then local by construction (GSPMD's transpose of the q-sharded
    softmax otherwise all-gathers the full fp32 cotangent)."""
    B, cq, KV, G, hd = q_chunk.shape
    mode = _head_sharding_mode(KV, G, cq)
    rules = rules_lib.current()
    ldt = jnp.dtype(logits_dtype)

    if mode == "qchunk" and rules is not None and kv_len is None:
        model_ax = rules.axis("tensor")
        batch_ax = rules.axis("batch")
        n = axis_extent("tensor")
        if isinstance(model_ax, str) and cq % n == 0 and \
                (batch_ax is None or B % axis_extent("batch") == 0):
            cq_local = cq // n
            qs = jnp.asarray(q_start, jnp.int32)

            @functools.partial(
                shard_map, mesh=rules.mesh,
                in_specs=(P(batch_ax, model_ax, None, None, None),
                          P(batch_ax, None, None, None),
                          P(batch_ax, None, None, None), P()),
                out_specs=P(batch_ax, model_ax, None, None, None),
                check_vma=False)
            def inner(qc, k_, v_, qs_):
                idx = jax.lax.axis_index(model_ax)
                return _attend_math(qc, k_, v_, qs_ + idx * cq_local,
                                    logits_dtype=ldt)

            return inner(q_chunk, k, v, qs)

    out = _attend_math(q_chunk, k, v, q_start, kv_len, logits_dtype=ldt)
    if mode == "heads":
        out = constrain(out, "batch", None, "heads", None, None)
    return out


def causal_attention(cfg: ModelConfig, q, k, v, *, unroll: bool) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    cq = min(cfg.q_chunk, S)
    n_chunks = (S + cq - 1) // cq
    if n_chunks * cq != S:  # pad seq to chunk multiple (rare)
        pad = n_chunks * cq - S
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qg = qg.reshape(B, n_chunks, cq, KV, G, hd)

    ldt = cfg.attn_logits_dtype
    if unroll or n_chunks == 1:
        outs = [_chunk_attend(qg[:, i], k, v, i * cq, logits_dtype=ldt)
                for i in range(n_chunks)]
        out = jnp.stack(outs, axis=1)
    else:
        def body(_, qc_i):
            qc, i = qc_i
            return None, _chunk_attend(qc, k, v, i * cq, logits_dtype=ldt)

        _, out = jax.lax.scan(body, None,
                              (jnp.moveaxis(qg, 1, 0), jnp.arange(n_chunks)))
        out = jnp.moveaxis(out, 0, 1)
    out = out.reshape(B, n_chunks * cq, KV, G, hd)[:, :S]
    return out.reshape(B, S, H, hd)


def attention_block(cfg: ModelConfig, p: dict, x: jnp.ndarray, positions,
                    *, unroll: bool) -> jnp.ndarray:
    """Full-sequence (train / prefill) attention sublayer (no residual/norm)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(cfg, p, x, positions)
    out = causal_attention(cfg, q, k, v, unroll=unroll)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), p["wo"])
    return constrain(out, "batch", "seq", "embed")


def attention_decode(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                     cache_k: jnp.ndarray, cache_v: jnp.ndarray, pos
                     ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: (B, 1, D); cache_k/v: (B, Smax, KV, hd);
    pos: scalar current position shared by all lanes (static batch), or a
    (B,) vector of per-lane positions (continuous batching: each lane's
    cache write, RoPE phase, and causal mask follow its own position).
    Returns (out, new_k, new_v)."""
    B, _, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    per_lane = jnp.ndim(pos) == 1
    if per_lane:
        pos = jnp.asarray(pos, jnp.int32)
        positions = pos[:, None] if not cfg.mrope else \
            jnp.broadcast_to(pos[None, :, None], (3, B, 1))
    else:
        positions = jnp.full((B, 1), pos, jnp.int32) if not cfg.mrope else \
            jnp.full((3, B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions)
    if per_lane:
        write = jax.vmap(
            lambda c, u, pb: jax.lax.dynamic_update_slice(c, u, (pb, 0, 0)))
        cache_k = write(cache_k, k.astype(cache_k.dtype), pos)
        cache_v = write(cache_v, v.astype(cache_v.dtype), pos)
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    qg = q.reshape(B, 1, KV, H // KV, hd)
    out = _chunk_attend(qg, cache_k, cache_v, pos, kv_len=pos + 1,
                        logits_dtype=cfg.attn_logits_dtype)
    out = out.reshape(B, 1, H * hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return constrain(out, "batch", None, "embed"), cache_k, cache_v
