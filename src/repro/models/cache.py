"""Decode caches + single-token decode step for every family.

Cache layouts (stacked over layers for lax.scan):
  attention: k/v (L, B, Smax, KV, hd) — seq dim SP-shardable ('kv_seq')
  mamba:     ssm (L, B, H, P, N) + conv (L, B, W-1, conv_dim)
  hybrid:    mamba caches for all L layers + attention k/v only at the
             shared-attention sites (n_sites, B, Smax, KV, hd)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.model import _dtype, embed_tokens, project_logits
from repro.sharding.rules import constrain

PyTree = Any


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, tuple]:
    L, B = cfg.num_layers, batch
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    shapes: Dict[str, tuple] = {}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "audio"):
        shapes["k"] = (L, B, max_seq, KV, hd)
        shapes["v"] = (L, B, max_seq, KV, hd)
    elif fam == "ssm":
        shapes["ssm"] = (L, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
        shapes["conv"] = (L, B, cfg.ssm_conv_width - 1,
                          cfg.d_inner + 2 * cfg.ssm_state)
    elif fam == "hybrid":
        n_sites = len(cfg.shared_attn_layers())
        shapes["ssm"] = (L, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
        shapes["conv"] = (L, B, cfg.ssm_conv_width - 1,
                          cfg.d_inner + 2 * cfg.ssm_state)
        shapes["k"] = (n_sites, B, max_seq, KV, hd)
        shapes["v"] = (n_sites, B, max_seq, KV, hd)
    else:
        raise ValueError(fam)
    return shapes


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    dt = _dtype(cfg)

    def mk(name, s):
        dtype = jnp.float32 if name in ("ssm",) else dt
        return jnp.zeros(s, dtype)

    return {k: mk(k, s) for k, s in cache_shapes(cfg, batch, max_seq).items()}


def reset_lanes(cache: PyTree, lane_mask: jnp.ndarray) -> PyTree:
    """Zero the cache contents of the lanes marked in ``lane_mask`` ((B,)
    bool) — the slot-reuse primitive: a freed batch lane is wiped before a
    queued request prefills into it.  Attention k/v beyond a lane's position
    are already masked out, but the SSM/conv states are cumulative, so a
    reused lane MUST be cleared.  Every cache layout keeps batch at axis 1
    (stacked-over-layers), so one broadcast covers all families."""
    def wipe(x):
        m = lane_mask.reshape((1, -1) + (1,) * (x.ndim - 2))
        return jnp.where(m, jnp.zeros((), x.dtype), x)

    return jax.tree.map(wipe, cache)


def cache_struct(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    dt = _dtype(cfg)
    out = {}
    for k, s in cache_shapes(cfg, batch, max_seq).items():
        out[k] = jax.ShapeDtypeStruct(s, jnp.float32 if k == "ssm" else dt)
    return out


# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params, cache: PyTree, batch, pos, *,
                unroll: bool = False):
    """One-token decode. batch: {'token': (B,1) / (B,1,K) / 'embed': (B,1,D)}.
    pos: scalar int32 — current write position (cache holds [0, pos) tokens)
    shared by every lane, or a (B,) int32 vector of per-lane positions for
    continuous batching (serve/engine.py: lanes decode at independent
    depths; attention masks/writes follow each lane's own position).
    ``unroll=True`` replaces layer scans with Python loops (roofline probes).
    Returns (logits, new_cache)."""
    tok_batch = dict(batch)
    if "token" in tok_batch:
        tok_batch["tokens"] = tok_batch.pop("token")
    if "embed" in tok_batch:
        tok_batch["embeds"] = tok_batch.pop("embed")
    x = embed_tokens(cfg, params, tok_batch)     # (B, 1, D)
    fam = cfg.family

    if fam in ("dense", "moe", "vlm", "audio"):
        x, new_cache = _decode_attn_stack(cfg, params, cache, x, pos, unroll)
    elif fam == "ssm":
        x, new_cache = _decode_ssm_stack(cfg, params, cache, x, unroll)
    elif fam == "hybrid":
        x, new_cache = _decode_hybrid_stack(cfg, params, cache, x, pos, unroll)
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return project_logits(cfg, params, x), new_cache


def _attn_sublayer_decode(cfg, p, x, ck, cv, pos):
    h = rms_norm(x, p["norm1"] if "norm1" in p else p["norm"], cfg.norm_eps)
    out, ck, cv = attn_lib.attention_decode(cfg, p["attn"], h, ck, cv, pos)
    return x + out, ck, cv


def _mlp_sublayer_decode(cfg, p, x):
    from repro.models.layers import gated_mlp
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return x + gated_mlp(cfg, p["mlp"], h)


def _moe_sublayer_decode(cfg, p, x):
    from repro.models.moe import moe_block
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return x + moe_block(cfg, p["moe"], h)


def _unrolled_scan(body, x, xs_tree):
    """Python-loop drop-in for lax.scan(body, x, xs) (probe mode)."""
    n = jax.tree.leaves(xs_tree)[0].shape[0]
    outs = []
    for i in range(n):
        xs_i = jax.tree.map(lambda a: a[i], xs_tree)
        x, out = body(x, xs_i)
        outs.append(out)
    stacked = jax.tree.map(lambda *ys: jnp.stack(ys, 0), *outs)
    return x, stacked


def _decode_attn_stack(cfg, params, cache, x, pos, unroll=False):
    scan = _unrolled_scan if unroll else jax.lax.scan

    def body(x, xs):
        p_i, ck, cv = xs
        x, ck, cv = _attn_sublayer_decode(cfg, p_i, x, ck, cv, pos)
        if "moe" in p_i:
            x = _moe_sublayer_decode(cfg, p_i, x)
        else:
            x = _mlp_sublayer_decode(cfg, p_i, x)
        return x, (ck, cv)

    if cfg.family == "moe":
        ks, vs = cache["k"], cache["v"]
        fd = cfg.first_dense_layers
        if fd:
            x, (k1, v1) = scan(
                body, x, (params["dense_layers"], ks[:fd], vs[:fd]))
            x, (k2, v2) = scan(
                body, x, (params["moe_layers"], ks[fd:], vs[fd:]))
            new_k = jnp.concatenate([k1, k2], 0)
            new_v = jnp.concatenate([v1, v2], 0)
        else:
            x, (new_k, new_v) = scan(
                body, x, (params["moe_layers"], ks, vs))
    else:
        x, (new_k, new_v) = scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
    return x, {"k": new_k, "v": new_v}


def _decode_ssm_stack(cfg, params, cache, x, unroll=False):
    scan = _unrolled_scan if unroll else jax.lax.scan

    def body(x, xs):
        p_i, st, cs = xs
        h = rms_norm(x, p_i["norm"], cfg.norm_eps)
        out, st, cs = ssm_lib.mamba_decode(cfg, p_i["mixer"], h, st, cs)
        return x + out, (st, cs)

    x, (ssm, conv) = scan(
        body, x, (params["layers"], cache["ssm"], cache["conv"]))
    return x, {"ssm": ssm, "conv": conv}


def _decode_hybrid_stack(cfg, params, cache, x, pos, unroll=False):
    scan = _unrolled_scan if unroll else jax.lax.scan
    L = cfg.num_layers
    sites = cfg.shared_attn_layers()
    is_site = jnp.array([i in sites for i in range(L)])
    site_idx = jnp.array([sites.index(i) if i in sites else 0
                          for i in range(L)], jnp.int32)
    shared = params["shared_attn"]

    def body(carry, xs):
        x, ak, av = carry
        p_i, st, cs, flag, sidx = xs

        def with_attn(args):
            x, ak, av = args
            ck = jax.lax.dynamic_index_in_dim(ak, sidx, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(av, sidx, 0, keepdims=False)
            h = rms_norm(x, shared["norm1"], cfg.norm_eps)
            out, ck, cv = attn_lib.attention_decode(cfg, shared["attn"], h,
                                                    ck, cv, pos)
            ak = jax.lax.dynamic_update_index_in_dim(ak, ck, sidx, 0)
            av = jax.lax.dynamic_update_index_in_dim(av, cv, sidx, 0)
            x = x + out
            x = _mlp_sublayer_decode(cfg, shared, x)
            return x, ak, av

        x, ak, av = jax.lax.cond(flag, with_attn, lambda a: a, (x, ak, av))
        h = rms_norm(x, p_i["norm"], cfg.norm_eps)
        out, st, cs = ssm_lib.mamba_decode(cfg, p_i["mixer"], h, st, cs)
        return (x + out, ak, av), (st, cs)

    (x, ak, av), (ssm, conv) = scan(
        body, (x, cache["k"], cache["v"]),
        (params["layers"], cache["ssm"], cache["conv"], is_site, site_idx))
    return x, {"ssm": ssm, "conv": conv, "k": ak, "v": av}
