"""Architecture config schema + analytic FLOP/param accounting."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    mrope: bool = False          # qwen2-vl M-RoPE (3 position streams)
    # mlp
    d_ff: int = 0
    mlp_act: str = "swiglu"      # swiglu | geglu
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    first_dense_layers: int = 0
    dense_ff: int = 0            # d_ff of the dense (non-MoE) layers
    capacity_factor: float = 1.25
    moe_impl: str = "auto"       # auto (shard_map under a mesh) | gspmd
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): shared attention block applied every k mamba layers
    attn_every: int = 0
    # embeddings / heads / modality
    tie_embeddings: bool = False
    embed_scale: bool = False    # gemma: embeds * sqrt(d_model)
    num_codebooks: int = 0       # musicgen: parallel EnCodec codebooks
    embed_inputs: bool = True    # False: frontend stub feeds embeddings (vlm)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True           # activation checkpointing per layer
    remat_policy: str = "full"   # full (save nothing) | dots (save matmul outs)
    # attention impl knobs
    q_chunk: int = 2048          # q-chunked causal attention block
    attn_logits_dtype: str = "float32"   # materialized softmax dtype in the
    # jnp fallback path (the Pallas flash kernel keeps f32 in VMEM only);
    # "bfloat16" halves the dominant HBM term for long-S training

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid-with-shared-attn)."""
        return self.family in ("ssm", "hybrid")

    def block_pattern(self) -> Tuple[Tuple[str, int], ...]:
        """((block_type, count), ...) — drives both the model composition and
        the probe cost solver (DESIGN.md §4)."""
        L = self.num_layers
        if self.family in ("dense", "vlm", "audio"):
            return (("dense", L),)
        if self.family == "moe":
            fd = self.first_dense_layers
            return (("dense", fd), ("moe", L - fd)) if fd else (("moe", L),)
        if self.family == "ssm":
            return (("mamba", L),)
        if self.family == "hybrid":
            n_attn = len(self.shared_attn_layers())
            return (("mamba", L), ("shared_attn", n_attn))
        raise ValueError(self.family)

    def shared_attn_layers(self) -> Tuple[int, ...]:
        if self.family != "hybrid" or not self.attn_every:
            return ()
        return tuple(range(0, self.num_layers, self.attn_every))

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count (exact for our parameterization)."""
        D, V = self.d_model, self.vocab_size
        total = 0
        # embeddings (+ untied head)
        n_embed = max(self.num_codebooks, 1)
        total += n_embed * V * D
        if not self.tie_embeddings:
            total += n_embed * V * D
        total += D  # final norm
        for kind, count in self.block_pattern():
            # shared_attn weights are reused across sites: counted once
            n = 1 if kind == "shared_attn" else count
            total += n * self.block_params(kind)
        return total

    def block_params(self, kind: str) -> int:
        D = self.d_model
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        if kind == "dense":
            attn = D * (H + 2 * KV) * hd + H * hd * D
            if self.qkv_bias:
                attn += (H + 2 * KV) * hd
            mlp = 3 * D * self.d_ff
            return attn + mlp + 2 * D  # two norms
        if kind == "moe":
            attn = D * (H + 2 * KV) * hd + H * hd * D
            if self.qkv_bias:
                attn += (H + 2 * KV) * hd
            router = D * self.num_experts
            experts = self.num_experts * 3 * D * self.d_ff
            shared = self.num_shared_experts * 3 * D * self.d_ff
            return attn + router + experts + shared + 2 * D
        if kind == "mamba":
            din, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            in_proj = D * (2 * din + 2 * N + Hs)
            conv = self.ssm_conv_width * (din + 2 * N)
            out_proj = din * D
            extras = 3 * Hs + din  # A_log, dt_bias, D, gated-norm scale
            return in_proj + conv + out_proj + extras + D
        if kind == "shared_attn":
            # zamba2 shared transformer block: attention + MLP, stored once
            attn = D * (H + 2 * KV) * hd + H * hd * D
            return attn + 3 * D * self.d_ff + 2 * D
        raise ValueError(kind)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        D = self.d_model
        total = self.param_count()
        inactive = (self.num_experts - self.experts_per_token)
        per_expert = 3 * D * self.d_ff
        moe_layers = sum(c for k, c in self.block_pattern() if k == "moe")
        total -= moe_layers * inactive * per_expert
        return total

    def model_flops_per_token(self, seq_len: int, *, training: bool,
                              decode: bool = False) -> float:
        """MODEL_FLOPS per token: 6·N_active (train) / 2·N_active (fwd)
        + attention term. ``decode``: one-token step against a seq_len cache."""
        N = self.active_param_count()
        base = (6 if training else 2) * N
        # attention flops per token: 2 matmuls * 2 flops * window
        H, hd = self.num_heads, self.head_dim
        n_attn = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            n_attn = self.num_layers
        elif self.family == "hybrid":
            n_attn = len(self.shared_attn_layers())
        window = seq_len if decode else seq_len / 2  # causal average
        attn = (3 if training else 1) * n_attn * 4 * H * hd * window
        # ssd flops per token: state update + output, linear in state
        n_ssm = self.num_layers if self.family in ("ssm", "hybrid") else 0
        ssd = (3 if training else 1) * n_ssm * 6 * self.d_inner * self.ssm_state
        return base + attn + ssd
