"""Shared layers: norms, RoPE / M-RoPE, gated MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections=None) -> jnp.ndarray:
    """x: (B, S, H, hd). positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): the hd/2 rotary frequencies are split into
    (temporal, height, width) sections, each rotated by its own position
    stream. For the text-only backbone all three streams coincide.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if positions.ndim == 2:                              # plain RoPE
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    else:                                                # M-RoPE (3, B, S)
        n = hd // 2
        if mrope_sections is None:
            s1 = n // 4
            s2 = (n - s1) // 2
            mrope_sections = (s1, s2, n - s1 - s2)      # qwen2-vl-like split
        parts = []
        start = 0
        for stream, sec in enumerate(mrope_sections):
            f = freqs[start:start + sec]
            parts.append(positions[stream][..., None].astype(jnp.float32) * f)
            start += sec
        angles = jnp.concatenate(parts, axis=-1)        # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)


def gated_mlp(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    from repro.sharding.rules import constrain

    act = jax.nn.silu if cfg.mlp_act == "swiglu" else \
        (lambda v: jax.nn.gelu(v, approximate=True))
    gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = act(gate) * up
    h = constrain(h, "batch", "seq", "tensor")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return constrain(out, "batch", "seq", "embed")
