"""Model composition: embeddings -> block stack -> head, for every family.

Two execution modes over the same stacked parameter pytree:
  - scan mode (production): lax.scan over layers (+remat) — fast compiles,
    low HLO size, realistic memory picture;
  - probe/unrolled mode: Python loops everywhere so compiled.cost_analysis()
    counts every layer/chunk (roofline probes, DESIGN.md §4).

Decode carries KV caches (attention), SSM+conv states (mamba), and for the
hybrid family a *sites-only* attention cache (zamba2's shared attention
appears every `attn_every` layers; caching only those sites divides cache
memory by ~attn_every).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import gated_mlp, rms_norm
from repro.sharding.rules import constrain

PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _remat(cfg: ModelConfig, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Parameter shape declarations


def _dense_layer_shapes(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    return {
        "attn": attn_lib.attn_params_shape(cfg),
        "mlp": {"w_gate": (cfg.d_model, d_ff), "w_up": (cfg.d_model, d_ff),
                "w_down": (d_ff, cfg.d_model)},
        "norm1": (cfg.d_model,),
        "norm2": (cfg.d_model,),
    }


def _moe_layer_shapes(cfg: ModelConfig) -> dict:
    return {
        "attn": attn_lib.attn_params_shape(cfg),
        "moe": moe_lib.moe_params_shape(cfg),
        "norm1": (cfg.d_model,),
        "norm2": (cfg.d_model,),
    }


def _mamba_layer_shapes(cfg: ModelConfig) -> dict:
    return {"mixer": ssm_lib.ssm_params_shape(cfg), "norm": (cfg.d_model,)}


def _stack(shapes: dict, n: int) -> dict:
    return jax.tree.map(lambda s: (n,) + tuple(s), shapes,
                        is_leaf=lambda x: isinstance(x, tuple))


def param_shapes(cfg: ModelConfig) -> PyTree:
    """Pytree of shape tuples for all parameters."""
    D, V = cfg.d_model, cfg.vocab_size
    K = max(cfg.num_codebooks, 1)
    shapes: Dict[str, Any] = {}
    if cfg.embed_inputs:
        shapes["embed"] = (K, V, D) if cfg.num_codebooks else (V, D)
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (K, D, V) if cfg.num_codebooks else (D, V)
    elif not cfg.embed_inputs:
        shapes["lm_head"] = (D, V)
    shapes["final_norm"] = (D,)

    fam = cfg.family
    L = cfg.num_layers
    if fam in ("dense", "vlm", "audio"):
        shapes["layers"] = _stack(_dense_layer_shapes(cfg), L)
    elif fam == "moe":
        fd = cfg.first_dense_layers
        if fd:
            shapes["dense_layers"] = _stack(
                _dense_layer_shapes(cfg, cfg.dense_ff or cfg.d_ff), fd)
        shapes["moe_layers"] = _stack(_moe_layer_shapes(cfg), L - fd)
    elif fam == "ssm":
        shapes["layers"] = _stack(_mamba_layer_shapes(cfg), L)
    elif fam == "hybrid":
        shapes["layers"] = _stack(_mamba_layer_shapes(cfg), L)
        # zamba2: ONE shared transformer block (attention + MLP) reused at
        # every site — parameters counted once, applied n_sites times.
        shapes["shared_attn"] = _dense_layer_shapes(cfg)
    else:
        raise ValueError(fam)
    return shapes


def param_struct(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStructs (no allocation) — dry-run input."""
    dt = _dtype(cfg)

    def leaf(s):
        return jax.ShapeDtypeStruct(tuple(s), dt)

    return jax.tree.map(leaf, param_shapes(cfg),
                        is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: ModelConfig, key) -> PyTree:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))
    dt = _dtype(cfg)

    leaves = []
    for s, k in zip(flat, keys):
        s = tuple(s)
        if len(s) == 1:
            leaves.append(jnp.zeros(s, dt))  # norms/bias -> 0 (scale adds 1)
        else:
            fan_in = s[-2] if len(s) >= 2 else s[-1]
            leaves.append((jax.random.normal(k, s, jnp.float32)
                           * (fan_in ** -0.5)).astype(dt))
    return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Blocks (train / prefill)


def _dense_block(cfg: ModelConfig, p, x, positions, unroll):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    x = x + attn_lib.attention_block(cfg, p["attn"], h, positions, unroll=unroll)
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + gated_mlp(cfg, p["mlp"], h)
    return x


def _moe_block(cfg: ModelConfig, p, x, positions, unroll):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    x = x + attn_lib.attention_block(cfg, p["attn"], h, positions, unroll=unroll)
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + moe_lib.moe_block(cfg, p["moe"], h)
    return x


def _mamba_layer(cfg: ModelConfig, p, x, unroll):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    return x + ssm_lib.mamba_block(cfg, p["mixer"], h, unroll=unroll)


def _shared_attn_apply(cfg: ModelConfig, p, x, positions, unroll):
    return _dense_block(cfg, p, x, positions, unroll)


def _run_stack(cfg, stacked, x, positions, block_fn, unroll, n_override=None):
    n = jax.tree.leaves(stacked)[0].shape[0] if n_override is None else n_override
    if unroll:
        fn = _remat(cfg, block_fn)
        for i in range(n):
            p_i = jax.tree.map(lambda a: a[i], stacked)
            x = fn(p_i, x)
        return x

    def body(carry, p_i):
        fn = _remat(cfg, block_fn)
        return fn(p_i, carry), None

    x, _ = jax.lax.scan(body, x, stacked)
    return x


def _hybrid_stack(cfg, params, x, positions, unroll):
    layers = params["layers"]
    shared = params["shared_attn"]
    L = cfg.num_layers
    sites = cfg.shared_attn_layers()
    is_site = jnp.array([i in sites for i in range(L)])

    def block(p_i, site_flag, x):
        def with_attn(x):
            return _shared_attn_apply(cfg, shared, x, positions, unroll)

        if unroll:
            x = with_attn(x) if bool(site_flag) else x
        else:
            x = jax.lax.cond(site_flag, with_attn, lambda v: v, x)
        return _mamba_layer(cfg, p_i, x, unroll)

    if unroll:
        for i in range(L):
            p_i = jax.tree.map(lambda a: a[i], layers)
            fn = _remat(cfg, functools.partial(block, p_i, bool(i in sites)))
            x = fn(x)
        return x

    def body(carry, xs):
        p_i, flag = xs
        fn = _remat(cfg, functools.partial(block, p_i, flag))
        return fn(carry), None

    x, _ = jax.lax.scan(body, x, (layers, is_site))
    return x


# ---------------------------------------------------------------------------
# Forward / loss


def embed_tokens(cfg: ModelConfig, params, batch):
    dt = _dtype(cfg)
    if not cfg.embed_inputs:
        x = batch["embeds"].astype(dt)          # modality-frontend stub
    elif cfg.num_codebooks:
        toks = batch["tokens"]                   # (B, S, K)
        emb = params["embed"]                    # (K, V, D)
        x = sum(emb[i][toks[..., i]] for i in range(cfg.num_codebooks))
        x = x.astype(dt)
    else:
        x = params["embed"][batch["tokens"]].astype(dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    return constrain(x, "batch", "seq", None)


def _positions(cfg: ModelConfig, batch, B, S):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.mrope:
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def forward(cfg: ModelConfig, params, batch, *, unroll: bool = False):
    """Returns logits: (B, S, V) or (B, S, K, V) for codebook models."""
    x = embed_tokens(cfg, params, batch)
    B, S, _ = x.shape
    positions = _positions(cfg, batch, B, S)

    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        x = _run_stack(cfg, params["layers"], x, positions,
                       lambda p, v: _dense_block(cfg, p, v, positions, unroll),
                       unroll)
    elif fam == "moe":
        if cfg.first_dense_layers:
            x = _run_stack(cfg, params["dense_layers"], x, positions,
                           lambda p, v: _dense_block(cfg, p, v, positions, unroll),
                           unroll)
        x = _run_stack(cfg, params["moe_layers"], x, positions,
                       lambda p, v: _moe_block(cfg, p, v, positions, unroll),
                       unroll)
    elif fam == "ssm":
        x = _run_stack(cfg, params["layers"], x, positions,
                       lambda p, v: _mamba_layer(cfg, p, v, unroll), unroll)
    elif fam == "hybrid":
        x = _hybrid_stack(cfg, params, x, positions, unroll)
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return project_logits(cfg, params, x)


def project_logits(cfg: ModelConfig, params, x):
    if cfg.num_codebooks:
        head = params["lm_head"]                    # (K, D, V)
        logits = jnp.einsum("bsd,kdv->bskv", x, head)
    elif cfg.tie_embeddings and cfg.embed_inputs:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return constrain(logits, "batch", "seq", "vocab")


def loss_fn(cfg: ModelConfig, params, batch, *, unroll: bool = False):
    logits = forward(cfg, params, batch, unroll=unroll).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("mask")
    if mask is not None:
        while mask.ndim < nll.ndim:
            mask = mask[..., None]
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
