"""Mixture-of-Experts block: shared + routed top-k experts with sort-based
capacity dispatch (DeepSeekMoE / Kimi-K2 style fine-grained experts).

Dispatch is O(T·k·log) gather/scatter — no dense (T, E) one-hot einsum, so
FLOPs and memory scale with *active* experts (capacity = cf·T·k/E per
expert). Under the production mesh the expert dim is EP-sharded over
'model'; GSPMD inserts the all-to-all-equivalent collectives around the
per-expert einsums.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.sharding import rules as rules_lib
from repro.sharding.rules import axis_extent, constrain, shard_map


def moe_params_shape(cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    shapes = {
        "router": (D, E),
        "experts": {
            "w_gate": (E, D, F),
            "w_up": (E, D, F),
            "w_down": (E, F, D),
        },
    }
    if cfg.num_shared_experts:
        Fs = cfg.num_shared_experts * cfg.d_ff
        shapes["shared"] = {"w_gate": (D, Fs), "w_up": (D, Fs),
                            "w_down": (Fs, D)}
    return shapes


def _route(cfg: ModelConfig, router, xt):
    """Top-k routing tables. xt: (T, D). Returns (gate_w, gate_idx)."""
    logits = jnp.einsum("td,de->te", xt, router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_w = gate_w / (jnp.sum(gate_w, axis=-1, keepdims=True) + 1e-9)
    return gate_w, gate_idx


def _slot_tables(E, k, capacity, gate_w, gate_idx, T):
    """Slot-indexed routing tables (D-free)."""
    flat_expert = gate_idx.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), k)
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_expert)
    se, stok, sw = flat_expert[order], flat_token[order], flat_w[order]
    counts = jnp.bincount(flat_expert, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k) - starts[se]
    keep = pos_in_e < capacity
    slot = jnp.where(keep, se * capacity + pos_in_e, E * capacity)
    slot_tok = jnp.full((E * capacity + 1,), T, jnp.int32).at[slot].set(stok)
    slot_w = jnp.zeros((E * capacity + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sw, 0.0))
    return slot_tok[:-1], slot_w[:-1]


def _experts_ffn(cfg, we, buf):
    act = jax.nn.silu if cfg.mlp_act == "swiglu" else \
        (lambda v: jax.nn.gelu(v, approximate=True))
    h = act(jnp.einsum("ecd,edf->ecf", buf, we["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, we["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, we["w_down"])


def _moe_routed_shard_map(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                          rules) -> jnp.ndarray:
    """Expert-parallel MoE with explicit locality (EXPERIMENTS.md §Perf,
    kimi iteration 4): tokens are replicated across the model axis, so each
    model shard gathers its own experts' tokens LOCALLY; the only collectives
    are the FSDP weight all-gathers and one psum of the (T_local, D) partial
    combine — GSPMD's generic lowering of the same graph moves the full
    (E*C, D) buffers instead."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    model_ax = rules.axis("experts")
    fsdp_ax = rules.axis("fsdp")
    batch_ax = rules.axis("batch")
    n_model = axis_extent("experts")
    E_loc = E // n_model

    in_specs = (
        P(batch_ax, None, None),                      # x
        P(fsdp_ax, None),                             # router (D, E)
        {"w_gate": P(model_ax, fsdp_ax, None),        # experts
         "w_up": P(model_ax, fsdp_ax, None),
         "w_down": P(model_ax, None, fsdp_ax)},
    )

    @functools.partial(shard_map, mesh=rules.mesh,
                       in_specs=in_specs,
                       out_specs=P(batch_ax, None, None),
                       check_vma=False)
    def body(x_loc, router_loc, we_loc):
        Bl, Sl, _ = x_loc.shape
        T = Bl * Sl
        xt = x_loc.reshape(T, D)
        if fsdp_ax is not None:
            router_full = jax.lax.all_gather(router_loc, fsdp_ax, axis=0,
                                             tiled=True)
            we_full = {
                "w_gate": jax.lax.all_gather(we_loc["w_gate"], fsdp_ax,
                                             axis=1, tiled=True),
                "w_up": jax.lax.all_gather(we_loc["w_up"], fsdp_ax,
                                           axis=1, tiled=True),
                "w_down": jax.lax.all_gather(we_loc["w_down"], fsdp_ax,
                                             axis=2, tiled=True),
            }
        else:
            router_full, we_full = router_loc, we_loc

        capacity = int(cfg.capacity_factor * T * k / E) + 1
        gate_w, gate_idx = _route(cfg, router_full, xt)
        slot_tok, slot_w = _slot_tables(E, k, capacity, gate_w, gate_idx, T)
        # local expert range (shard_map already gave us our E_loc weights)
        eidx = jax.lax.axis_index(model_ax) if model_ax else 0
        lo = eidx * E_loc * capacity
        slot_tok_loc = jax.lax.dynamic_slice_in_dim(slot_tok, lo,
                                                    E_loc * capacity)
        slot_w_loc = jax.lax.dynamic_slice_in_dim(slot_w, lo,
                                                  E_loc * capacity)

        xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), x.dtype)], axis=0)
        buf = xt_pad[slot_tok_loc].reshape(E_loc, capacity, D)
        out_buf = _experts_ffn(cfg, we_full, buf)
        contrib = out_buf.reshape(E_loc * capacity, D) * \
            slot_w_loc[:, None].astype(x.dtype)
        routed = jnp.zeros((T + 1, D), x.dtype).at[slot_tok_loc].add(
            contrib)[:T]
        if model_ax is not None:
            routed = jax.lax.psum(routed, model_ax)
        return routed.reshape(Bl, Sl, D)

    return body(x, p["router"], p["experts"])


def _shard_map_ok(cfg: ModelConfig, B: int) -> bool:
    rules = rules_lib.current()
    if rules is None or cfg.moe_impl == "gspmd":
        return False
    n_model = axis_extent("experts")
    n_batch = axis_extent("batch")
    model_ax = rules.axis("experts")
    return (isinstance(model_ax, str) and n_model > 1
            and cfg.num_experts % n_model == 0 and B % n_batch == 0)


def moe_block(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, D)

    if _shard_map_ok(cfg, B):
        routed = _moe_routed_shard_map(cfg, p, x, rules_lib.current())
        routed = routed.reshape(T, D)
        return _finish_moe(cfg, p, xt, routed, B, S, D)

    gate_w, gate_idx = _route(cfg, p["router"], xt)

    capacity = int(cfg.capacity_factor * T * k / E) + 1
    # slot-indexed routing tables: all (E*C,)-shaped, D-free. The naive
    # formulation gathers/scatters (T*k, D) tensors, which GSPMD replicates
    # and all-reduces at ~1TB/device/layer for kimi-scale MoE
    # (EXPERIMENTS.md §Perf, kimi iteration 1).
    slot_tok, slot_w = _slot_tables(E, k, capacity, gate_w, gate_idx, T)

    # dispatch: one (E*C, D) gather from the padded token table
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), x.dtype)], axis=0)
    buf = xt_pad[slot_tok].reshape(E, capacity, D)
    buf = constrain(buf, "experts", None, None)
    out_buf = _experts_ffn(cfg, p["experts"], buf)
    out_buf = constrain(out_buf, "experts", None, None)

    # combine: weight in expert-sharded layout, one scatter-add to tokens
    contrib = out_buf.reshape(E * capacity, D) * slot_w[:, None].astype(x.dtype)
    routed = jnp.zeros((T + 1, D), x.dtype).at[slot_tok].add(contrib)[:T]
    return _finish_moe(cfg, p, xt, routed, B, S, D)


def _finish_moe(cfg, p, xt, routed, B, S, D):
    act = jax.nn.silu if cfg.mlp_act == "swiglu" else \
        (lambda v: jax.nn.gelu(v, approximate=True))
    out = routed
    if cfg.num_shared_experts:
        sh = p["shared"]
        hs = act(jnp.einsum("td,df->tf", xt, sh["w_gate"])) * \
            jnp.einsum("td,df->tf", xt, sh["w_up"])
        out = out + jnp.einsum("tf,fd->td", hs, sh["w_down"])
    out = out.reshape(B, S, D)
    return constrain(out, "batch", "seq", "embed")
