"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) blocks.

Chunked SSD: within-chunk attention-like term + inter-chunk state recurrence
(lax.scan over chunks; Python loop in probe mode so cost_analysis sees every
chunk — DESIGN.md §4). Single B/C group (n_groups=1) as in mamba2-370m.

Decode keeps O(H·P·N) recurrent state + a (w-1)-token conv window — this is
what makes the long_500k cells feasible for the SSM/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.sharding.rules import constrain


def ssm_params_shape(cfg: ModelConfig) -> dict:
    D, din, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * N
    return {
        "in_proj": (D, 2 * din + 2 * N + H),
        "conv_w": (cfg.ssm_conv_width, conv_dim),
        "conv_b": (conv_dim,),
        "A_log": (H,),
        "dt_bias": (H,),
        "ssm_D": (H,),
        "gate_norm": (din,),
        "out_proj": (din, D),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init_state: jnp.ndarray = None) -> jnp.ndarray:
    """Depthwise causal conv1d. xbc: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = init_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def _ssd_chunk(u_c, dlog_c, B_c, C_c, state):
    """One SSD chunk. u_c: (B,Q,H,P); dlog_c: (B,Q,H); B_c/C_c: (B,Q,N);
    state: (B,H,P,N). Returns (y_c, new_state)."""
    A_cs = jnp.cumsum(dlog_c, axis=1)                    # (B,Q,H)
    # intra-chunk: y[q] = sum_{s<=q} (C_q.B_s) exp(A_cs[q]-A_cs[s]) u[s]
    scores = jnp.einsum("bqn,bsn->bqs", C_c, B_c)        # (B,Q,S)
    dec = A_cs[:, :, None, :] - A_cs[:, None, :, :]      # (B,Q,S,H)
    Q = u_c.shape[1]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, :, :, None], jnp.exp(dec), 0.0)
    y_intra = jnp.einsum("bqs,bqsh,bshp->bqhp", scores, L, u_c)
    # inter-chunk: contribution of carried state
    dec_q = jnp.exp(A_cs)                                 # (B,Q,H)
    y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", C_c, dec_q, state)
    # new state: decay old + within-chunk accumulation
    dec_end = jnp.exp(A_cs[:, -1:, :] - A_cs)             # (B,Q,H)
    new_state = jnp.einsum("bqh,bqn,bqhp->bhpn", dec_end, B_c, u_c) + \
        jnp.exp(A_cs[:, -1])[:, :, None, None] * state
    return y_intra + y_inter, new_state


def ssd(u, dlog, Bm, Cm, chunk: int, *, unroll: bool):
    """u: (B,S,H,P); dlog: (B,S,H); Bm/Cm: (B,S,N). Linear-time scan."""
    B, S, H, P = u.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dlog = jnp.pad(dlog, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    n = Sp // Q
    N = Bm.shape[-1]

    uc = u.reshape(B, n, Q, H, P)
    dc = dlog.reshape(B, n, Q, H)
    Bc = Bm.reshape(B, n, Q, N)
    Cc = Cm.reshape(B, n, Q, N)

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    if unroll or n == 1:
        ys, state = [], state0
        for i in range(n):
            y, state = _ssd_chunk(uc[:, i].astype(jnp.float32),
                                  dc[:, i].astype(jnp.float32),
                                  Bc[:, i].astype(jnp.float32),
                                  Cc[:, i].astype(jnp.float32), state)
            ys.append(y)
        y = jnp.stack(ys, axis=1)
    else:
        def body(state, xs):
            u_i, d_i, B_i, C_i = xs
            y, state = _ssd_chunk(u_i.astype(jnp.float32),
                                  d_i.astype(jnp.float32),
                                  B_i.astype(jnp.float32),
                                  C_i.astype(jnp.float32), state)
            return state, y

        _, y = jax.lax.scan(body, state0,
                            (jnp.moveaxis(uc, 1, 0), jnp.moveaxis(dc, 1, 0),
                             jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0)))
        y = jnp.moveaxis(y, 0, 1)
    y = y.reshape(B, Sp, H, P)[:, :S]
    return y.astype(u.dtype)


def mamba_block(cfg: ModelConfig, p: dict, x: jnp.ndarray, *,
                unroll: bool) -> jnp.ndarray:
    """Full Mamba2 mixer (train/prefill). x: (B, S, D)."""
    B, S, D = x.shape
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    zxbcdt = constrain(zxbcdt, "batch", "seq", "tensor")
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * N]
    dt_raw = zxbcdt[..., -H:]

    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xc = xbc[..., :din]
    Bm = xbc[..., din:din + N]
    Cm = xbc[..., din + N:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                      # (H,)
    u = xc.reshape(B, S, H, P)
    y = ssd(u * dt[..., None].astype(u.dtype), dt * A, Bm, Cm,
            cfg.ssm_chunk, unroll=unroll)
    y = y + p["ssm_D"].astype(y.dtype)[None, None, :, None] * u
    y = y.reshape(B, S, din)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return constrain(out, "batch", "seq", "embed")


def mamba_decode(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                 ssm_state: jnp.ndarray, conv_state: jnp.ndarray
                 ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode. x: (B, 1, D); ssm_state: (B,H,P,N);
    conv_state: (B, W-1, conv_dim)."""
    B = x.shape[0]
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + din + 2 * N]      # (B,1,conv_dim)
    dt_raw = zxbcdt[..., -H:]

    window = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    w = p["conv_w"]
    conv_out = sum(window[:, i] * w[i] for i in range(w.shape[0]))
    conv_out = jax.nn.silu(conv_out + p["conv_b"])[:, None]  # (B,1,conv_dim)
    new_conv_state = window[:, 1:]

    xc = conv_out[..., :din]
    Bm = conv_out[..., din:din + N][:, 0]          # (B,N)
    Cm = conv_out[..., din + N:][:, 0]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                            # (B,H)
    u = xc.reshape(B, H, P).astype(jnp.float32) * dt[..., None]
    new_state = a[:, :, None, None] * ssm_state + \
        jnp.einsum("bhp,bn->bhpn", u, Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(jnp.float32))
    y = y + p["ssm_D"].astype(jnp.float32)[None, :, None] * \
        xc.reshape(B, H, P).astype(jnp.float32)
    y = y.reshape(B, 1, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, new_state, new_conv_state
