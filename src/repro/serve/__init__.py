"""Serving: continuous-batching engine + FD telemetry + online adaptation.

  engine.py   — session-style Engine (submit/step/drain, slot reuse)
  monitor.py  — FD-sketch gradient monitor (drift/pressure/spike policy)
  adapt.py    — S-AdaGrad online adaptation of the head from feedback
  loadgen.py  — deterministic constant/step traffic generator
"""
from repro.serve.adapt import AdaptConfig, OnlineAdapter
from repro.serve.engine import (Engine, Request, RequestHandle, Result,
                                ServeConfig)
from repro.serve.loadgen import LoadGenerator, TrafficConfig
from repro.serve.monitor import (ADAPT, PAUSE, STEADY, GradientMonitor,
                                 MonitorConfig, MonitorReading)

__all__ = [
    "AdaptConfig", "OnlineAdapter",
    "Engine", "Request", "RequestHandle", "Result", "ServeConfig",
    "LoadGenerator", "TrafficConfig",
    "GradientMonitor", "MonitorConfig", "MonitorReading",
    "STEADY", "ADAPT", "PAUSE",
]
