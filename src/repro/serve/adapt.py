"""Serve-time online adaptation: S-AdaGrad on the head from live feedback.

Bridges the paper's OCO setting (Sec. 2 / Alg. 2) to serving: the model's
head weights are treated as the online decision vector, each live-traffic
feedback batch provides one loss/gradient, and the S-AdaGrad engine step
(``core/sadagrad.sadagrad`` — FD sketch + rho compensation, ``beta2 < 1``
forgetting under drift) updates the head between decode steps.

The optimizer chain is built through ``api.inject_hyperparams``, so
``set_hyperparams(learning_rate=..., beta2=...)`` mutates the live values in
optimizer state — no chain rebuild, no retrace (the test suite pins the
trace count).  The decision of *when* to step belongs to the caller, driven
by serve/monitor.py's per-window policy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import api, transform
from repro.core.sadagrad import SAdaGradPreconditioner
from repro.models import model as model_lib
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    lr: float = 0.1       # online learning rate (injected, runtime-mutable)
    beta2: float = 0.99   # FD sketch EMA decay (injected, runtime-mutable)
    ell: int = 8          # sketch rank over the flattened head


def _pick_leaf(params) -> str:
    # the adapted decision vector: the output head when untied, else the
    # tied embedding matrix (which then IS the head)
    return "lm_head" if "lm_head" in params else "embed"


class OnlineAdapter:
    """S-AdaGrad online learner over the flattened head leaf.

    ``grad(params, batch)``  -> (loss, flat_grad)   — telemetry only (feeds
                                                      serve/monitor.py)
    ``step(params, batch)``  -> (new_params, loss)  — one OCO update
    ``set_hyperparams(...)``                        — runtime lr/beta2
    """

    def __init__(self, cfg: ModelConfig, params, adapt: AdaptConfig = None):
        self.cfg = cfg
        self.adapt = adapt = adapt or AdaptConfig()
        self._leaf = _pick_leaf(params)
        self._shape = params[self._leaf].shape
        self._dtype = params[self._leaf].dtype
        self.d = int(jnp.size(params[self._leaf]))
        self.trace_count = 0    # bumped inside the traced step body

        def build(learning_rate, beta2):
            # state structure is independent of the (possibly traced)
            # hyperparameter values — the inject_hyperparams contract
            return api.named_chain(
                ("precond", api.scale_by_preconditioner(
                    SAdaGradPreconditioner(adapt.ell, beta2),
                    api.EngineConfig(block_size=1 << 30, beta2=1.0,
                                     update_every=1, graft="none",
                                     treat_vectors_as_columns=True))),
                ("lr", transform.scale(-learning_rate)))

        self._tx = api.inject_hyperparams(build)(
            learning_rate=adapt.lr, beta2=adapt.beta2)
        self.opt_state = self._tx.init(
            jnp.zeros((self.d,), jnp.float32))

        def loss_flat(w, params, batch):
            p = dict(params)
            p[self._leaf] = w.reshape(self._shape).astype(self._dtype)
            return model_lib.loss_fn(cfg, p, batch)

        def grad_fn(params, batch):
            w = params[self._leaf].astype(jnp.float32).reshape(-1)
            return jax.value_and_grad(loss_flat)(w, params, batch)

        def step_fn(params, opt_state, batch):
            self.trace_count += 1     # python side effect: counts retraces
            w = params[self._leaf].astype(jnp.float32).reshape(-1)
            loss, g = jax.value_and_grad(loss_flat)(w, params, batch)
            update, opt_state = self._tx.update(g, opt_state)
            new_leaf = (w + update).reshape(self._shape).astype(self._dtype)
            return new_leaf, opt_state, loss, g

        self._grad = jax.jit(grad_fn)
        self._step = jax.jit(step_fn)

    # -- telemetry ----------------------------------------------------------

    def grad(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Feedback loss and flattened head gradient, no update (the
        monitor observes these even while adaptation is paused)."""
        return self._grad(params, batch)

    # -- the OCO step -------------------------------------------------------

    def step(self, params, batch):
        """One S-AdaGrad update on the head; returns (new_params, loss)."""
        new_leaf, self.opt_state, loss, _ = self._step(
            params, self.opt_state, batch)
        new_params = dict(params)
        new_params[self._leaf] = new_leaf
        return new_params, loss

    # -- runtime hyperparameters --------------------------------------------

    def set_hyperparams(self, **overrides) -> None:
        """Mutate lr/beta2 in optimizer state (api.set_hyperparams) — takes
        effect next step with NO retrace; KeyError on unknown names."""
        self.opt_state = api.set_hyperparams(self.opt_state, **overrides)

    @property
    def hyperparams(self) -> Dict[str, float]:
        return {k: float(v)
                for k, v in api.get_hyperparams(self.opt_state).items()}
