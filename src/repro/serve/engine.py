"""Batched decode serving engine (small-scale runnable; the 32k/500k decode
configurations are exercised via the dry-run).

Prefill is executed through the decode path token-by-token in chunks of the
request batch — adequate for the CPU example scale; on real hardware the
prefill would lower ``forward`` + cache-write (see launch/dryrun.py's
prefill cells for the compiled artifact).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (P,) int32 prompt tokens
    max_new_tokens: int = 16
    temperature: float = 0.0    # 0 => greedy


@dataclasses.dataclass
class Result:
    tokens: List[int]


class Engine:
    """Static-batch engine: pads requests to a common grid and steps."""

    def __init__(self, cfg: ModelConfig, params, max_seq: int, batch: int):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self._step = jax.jit(
            lambda p, c, b, pos: cache_lib.decode_step(cfg, p, c, b, pos))

    def generate(self, requests: List[Request], seed: int = 0) -> List[Result]:
        cfg = self.cfg
        assert len(requests) <= self.batch
        B = self.batch
        cache = cache_lib.init_cache(cfg, B, self.max_seq)
        prompts = [r.prompt for r in requests]
        max_p = max(len(p) for p in prompts)
        max_new = max(r.max_new_tokens for r in requests)
        toks = np.zeros((B, max_p), np.int32)
        plens = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
            plens[i] = len(p)

        outs: List[List[int]] = [[] for _ in range(B)]
        key = jax.random.PRNGKey(seed)
        last = jnp.asarray(toks[:, :1])
        for pos in range(max_p + max_new - 1):
            batch = {"token": last}
            logits, cache = self._step(self.params, cache,
                                       batch, jnp.asarray(pos, jnp.int32))
            logits = logits[:, -1]
            key, sub = jax.random.split(key)
            greedy = jnp.argmax(logits, axis=-1)
            sampled = jax.random.categorical(sub, logits / max(
                max(r.temperature for r in requests), 1e-6), axis=-1)
            temp = max(r.temperature for r in requests)
            nxt = np.asarray(sampled if temp > 0 else greedy)
            cur = np.zeros((B,), np.int32)
            for i in range(B):
                if pos + 1 < plens[i]:
                    cur[i] = toks[i, pos + 1]       # still prefilling
                else:
                    cur[i] = nxt[i]
                    if i < len(requests) and \
                            len(outs[i]) < requests[i].max_new_tokens:
                        outs[i].append(int(nxt[i]))
            last = jnp.asarray(cur)[:, None]
        return [Result(tokens=outs[i]) for i in range(len(requests))]
