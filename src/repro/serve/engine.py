"""Continuous-batching decode engine with slot reuse (session-style API).

The serving surface is ``submit`` / ``step`` / ``drain``:

    engine = Engine(cfg, params, ServeConfig(batch=4, max_seq=64))
    h = engine.submit(Request(prompt, max_new_tokens=12))
    while not h.done:
        engine.step()
    print(h.tokens)

Each of the ``ServeConfig.batch`` lanes runs at its own sequence position
(``models/cache.decode_step`` takes a (B,) position vector): a short request
frees its lane the step it finishes and the next queued request prefills
into the wiped slot (``cache_lib.reset_lanes``) while its co-tenants keep
decoding — no padding to the longest request in flight.  Per-request
``max_new_tokens`` and ``temperature`` are honored per lane (the old static
path generated ``max(...)`` new tokens for everyone and applied request 0's
temperature batch-wide).

The legacy one-shot ``Engine.generate(List[Request]) -> List[Result]`` is
kept as a thin deprecated wrapper over submit/drain (see the CHANGES.md
migration table).

Prefill is executed through the decode path token-by-token per lane —
adequate for the CPU example scale; on real hardware the prefill would
lower ``forward`` + cache-write (see launch/dryrun.py's prefill cells for
the compiled artifact).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import cache as cache_lib
from repro.models.config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    """Engine-level serving knobs (the per-request knobs live on Request)."""
    batch: int = 4        # number of batch lanes (requests decoding at once)
    max_seq: int = 64     # per-lane cache capacity (prompt + generated)
    seed: int = 0         # sampling PRNG seed


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (P,) int32 prompt tokens
    max_new_tokens: int = 16
    temperature: float = 0.0    # 0 => greedy


@dataclasses.dataclass
class Result:
    tokens: List[int]


class RequestHandle:
    """Ticket returned by ``Engine.submit``; filled in as the engine steps.

    ``tokens`` grows one entry per emitted token; ``token_times`` records a
    wall-clock stamp per emission (the load-generator benchmark reads
    inter-token latencies off these).  ``done`` flips when
    ``max_new_tokens`` have been emitted and the lane is freed.
    """

    def __init__(self, rid: int, request: Request, submit_step: int):
        self.id = rid
        self.request = request
        self.tokens: List[int] = []
        self.token_times: List[float] = []
        self.done = False
        self.submit_step = submit_step      # engine step count at submit
        self.start_step: Optional[int] = None   # lane assignment
        self.finish_step: Optional[int] = None

    @property
    def result(self) -> Result:
        return Result(tokens=list(self.tokens))

    def __repr__(self):
        state = "done" if self.done else \
            ("active" if self.start_step is not None else "queued")
        return (f"RequestHandle(id={self.id}, {state}, "
                f"tokens={len(self.tokens)}/{self.request.max_new_tokens})")


class Engine:
    """Continuous-batching engine: per-lane positions, slot reuse, queueing."""

    def __init__(self, cfg: ModelConfig, params, serve: ServeConfig = None, *,
                 max_seq: int = None, batch: int = None):
        if not cfg.embed_inputs or cfg.num_codebooks:
            raise ValueError(
                f"serving supports token-input archs only; {cfg.name!r} has "
                f"embed_inputs={cfg.embed_inputs} "
                f"num_codebooks={cfg.num_codebooks}")
        if serve is None:
            serve = ServeConfig()
        if max_seq is not None or batch is not None:   # legacy kw spelling
            serve = dataclasses.replace(
                serve, **({"max_seq": max_seq} if max_seq else {}),
                **({"batch": batch} if batch else {}))
        self.cfg = cfg
        self.params = params
        self.serve = serve
        self.max_seq = serve.max_seq       # legacy attribute names
        self.batch = serve.batch
        B = serve.batch

        self.cache = cache_lib.init_cache(cfg, B, serve.max_seq)
        self.lane_pos = np.zeros((B,), np.int32)    # tokens cached per lane
        self._fresh = np.zeros((B,), bool)          # wipe lane before step
        self.lanes: List[Optional[RequestHandle]] = [None] * B
        self.queue: Deque[RequestHandle] = collections.deque()
        self.step_count = 0
        self._next_id = 0
        self._key = jax.random.PRNGKey(serve.seed)

        def _step(params, cache, tokens, pos, temps, fresh, key):
            # tokens (B,1) int32; pos/temps/fresh (B,): one fused dispatch
            # per engine step — lane wipe, decode, per-lane sampling
            cache = cache_lib.reset_lanes(cache, fresh)
            logits, cache = cache_lib.decode_step(
                cfg, params, cache, {"token": tokens}, pos)
            logits = logits[:, -1]                          # (B, V)
            greedy = jnp.argmax(logits, axis=-1)
            scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
            sampled = jax.random.categorical(key, scaled, axis=-1)
            nxt = jnp.where(temps > 0, sampled, greedy)
            return nxt, cache

        self._step = jax.jit(_step)

    # -- session API --------------------------------------------------------

    def submit(self, request: Request) -> RequestHandle:
        """Queue a request; it claims a batch lane as soon as one is free."""
        P = len(request.prompt)
        if request.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{request.max_new_tokens}")
        if P < 1:
            raise ValueError("empty prompt")
        if P + request.max_new_tokens > self.serve.max_seq:
            raise ValueError(
                f"prompt ({P}) + max_new_tokens ({request.max_new_tokens}) "
                f"exceeds max_seq={self.serve.max_seq}")
        handle = RequestHandle(self._next_id, request, self.step_count)
        self._next_id += 1
        self.queue.append(handle)
        self._fill_lanes()
        return handle

    def _fill_lanes(self) -> None:
        for i in range(self.serve.batch):
            if self.lanes[i] is None and self.queue:
                h = self.queue.popleft()
                self.lanes[i] = h
                self.lane_pos[i] = 0
                self._fresh[i] = True
                h.start_step = self.step_count

    @property
    def active(self) -> int:
        return sum(h is not None for h in self.lanes)

    @property
    def pending(self) -> int:
        return len(self.queue)

    def step(self) -> List[RequestHandle]:
        """Advance every active lane by one token; returns the handles that
        completed this step (their lanes are freed for the queue)."""
        self._fill_lanes()
        if self.active == 0:
            return []
        B = self.serve.batch
        tokens = np.zeros((B, 1), np.int32)
        temps = np.zeros((B,), np.float32)
        for i, h in enumerate(self.lanes):
            if h is None:
                continue
            pos = int(self.lane_pos[i])
            prompt = h.request.prompt
            # the lane's sequence is prompt + generated; feed the token at
            # the lane's current position
            tokens[i, 0] = prompt[pos] if pos < len(prompt) \
                else h.tokens[pos - len(prompt)]
            temps[i] = h.request.temperature

        self._key, sub = jax.random.split(self._key)
        nxt, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.lane_pos), jnp.asarray(temps),
            jnp.asarray(self._fresh), sub)
        nxt = np.asarray(nxt)
        self._fresh[:] = False
        self.step_count += 1

        now = time.perf_counter()
        completed: List[RequestHandle] = []
        for i, h in enumerate(self.lanes):
            if h is None:
                continue
            self.lane_pos[i] += 1
            if self.lane_pos[i] >= len(h.request.prompt):
                # the model's output at this position is a generated token
                h.tokens.append(int(nxt[i]))
                h.token_times.append(now)
                if len(h.tokens) >= h.request.max_new_tokens:
                    h.done = True
                    h.finish_step = self.step_count
                    self.lanes[i] = None        # slot reuse: free the lane
                    completed.append(h)
        return completed

    def drain(self) -> List[RequestHandle]:
        """Step until every queued and active request completes; returns the
        completed handles in submission order."""
        done: List[RequestHandle] = []
        while self.queue or self.active:
            done.extend(self.step())
        return sorted(done, key=lambda h: h.id)

    # -- legacy one-shot API (deprecated) -----------------------------------

    def generate(self, requests: List[Request], seed: int = 0) -> List[Result]:
        """Deprecated compat wrapper over submit/step/drain (CHANGES.md
        migration table).  Unlike the old static-batch implementation, each
        request stops at ITS OWN ``max_new_tokens`` (no whole-batch
        ``max(...)`` over-generation) and samples at ITS OWN temperature."""
        if len(requests) > self.serve.batch:
            # the session API queues instead; the one-shot wrapper keeps the
            # old contract but fails cleanly rather than via assert
            raise ValueError(f"{len(requests)} requests > "
                             f"{self.serve.batch} lanes; use submit()/drain()")
        self._key = jax.random.PRNGKey(seed)
        handles = [self.submit(r) for r in requests]
        self.drain()
        return [h.result for h in handles]
