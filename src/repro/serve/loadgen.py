"""Deterministic load generator for the serving benchmarks and tests.

Produces uview-style traffic shapes (ROADMAP): a tick-based arrival process
where each tick draws a Poisson number of requests at the shape's current
rate.  Two shapes:

  * ``constant`` — fixed ``rate`` requests/tick for ``ticks`` ticks.
  * ``step``     — ``rate`` until ``step_at``, then ``rate * step_mult``
                   (the load spike the p99 latency row is about).

Arrivals are deterministic given ``seed``: every tick uses its own
seeded generator, so ``arrivals(t)`` is pure — benchmarks and tests replay
identical traffic regardless of call order.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.serve.engine import Request

SHAPES = ("constant", "step")


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    shape: str = "constant"   # constant | step
    rate: float = 1.0         # mean requests per tick
    ticks: int = 32           # total ticks in the run
    step_at: int = 16         # (step) tick where the rate jumps
    step_mult: float = 4.0    # (step) rate multiplier after the jump
    prompt_len: int = 8       # prompt tokens per request
    new_tokens: int = 8       # max_new_tokens per request
    temperature: float = 0.0  # per-request sampling temperature
    seed: int = 0

    def __post_init__(self):
        if self.shape not in SHAPES:
            raise ValueError(f"shape must be one of {SHAPES}, got "
                             f"{self.shape!r}")
        if self.rate < 0:
            raise ValueError(f"rate must be >= 0, got {self.rate}")


class LoadGenerator:
    """Replayable request stream: ``arrivals(tick) -> List[Request]``."""

    def __init__(self, cfg: TrafficConfig, vocab_size: int):
        self.cfg = cfg
        self.vocab_size = vocab_size

    def rate_at(self, tick: int) -> float:
        cfg = self.cfg
        if cfg.shape == "step" and tick >= cfg.step_at:
            return cfg.rate * cfg.step_mult
        return cfg.rate

    def arrivals(self, tick: int) -> List[Request]:
        cfg = self.cfg
        rng = np.random.default_rng([cfg.seed, tick])   # pure per tick
        n = int(rng.poisson(self.rate_at(tick)))
        return [Request(
            prompt=rng.integers(0, self.vocab_size, size=(cfg.prompt_len,),
                                dtype=np.int32),
            max_new_tokens=cfg.new_tokens,
            temperature=cfg.temperature) for _ in range(n)]

    def total_expected(self) -> float:
        return sum(self.rate_at(t) for t in range(self.cfg.ticks))
