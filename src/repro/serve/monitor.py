"""FD-sketch gradient monitor: drift/health telemetry for serve-time traffic.

The paper's core object — a cheap Frequent-Directions sketch tracking the
leading eigenspace of the gradient covariance — doubles as a low-overhead
*monitor* of the feedback-gradient stream (sketching-for-gradient-monitoring
/ uview-style FD monitors; see PAPERS.md).  Per window of ``window``
feedback gradients the monitor maintains a fresh rank-``ell`` sketch via
``core/fd.fd_update`` and, at the window boundary, reads three signals off
it:

  * ``leading_eig``  — top eigenvalue of the compensated window sketch
    (``fd_leading_eigval``): tracks gradient energy; a sudden spike means
    suspected bad traffic (poisoned/garbage feedback), not honest drift.
  * ``pressure``     — escaped-mass ratio ``rho/(trace+rho)``
    (``fd_pressure``): how much of the window's gradient mass escapes the
    rank-``ell`` subspace; rises when the stream stops being low-rank.
  * ``drift_angle``  — largest principal angle between this window's and
    the previous window's leading sketch subspaces (``fd_subspace_angle``):
    rises when the gradient subspace rotates, the signature of a
    distribution shift.

A threshold policy turns the signals into a decision per window — "steady"
(do nothing), "adapt" (run the online-adaptation loop, serve/adapt.py), or
"pause" (suspected bad traffic: hold adaptation until the spike passes).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core.fd import (fd_init, fd_leading_eigval, fd_pressure,
                           fd_subspace_angle, fd_update)

# window-boundary decisions, in escalation order
STEADY, ADAPT, PAUSE = "steady", "adapt", "pause"


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    ell: int = 8                  # sketch rank per window
    window: int = 8               # feedback gradients per window
    top_k: int = 4                # subspace columns compared for drift
    drift_threshold: float = 0.8      # radians; pi/2 = fully rotated
    pressure_threshold: float = 0.35  # rho/(trace+rho)
    spike_factor: float = 25.0    # leading-eig jump vs EMA => pause
    eig_ema: float = 0.7          # EMA decay for the leading-eig trajectory
    warmup_windows: int = 1       # windows before decisions are issued

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if not (1 <= self.top_k <= self.ell):
            raise ValueError(f"need 1 <= top_k <= ell, got "
                             f"top_k={self.top_k} ell={self.ell}")


@dataclasses.dataclass
class MonitorReading:
    """One window-boundary observation (the monitor's public record)."""
    window: int           # 0-based window index
    leading_eig: float
    pressure: float
    drift_angle: float    # radians vs the previous window's subspace
    decision: str         # steady | adapt | pause

    def __str__(self):
        return (f"window {self.window}: leading_eig={self.leading_eig:.3e} "
                f"pressure={self.pressure:.3f} "
                f"drift={self.drift_angle:.2f}rad -> {self.decision}")


class GradientMonitor:
    """Feed flattened feedback gradients; get a MonitorReading per window.

    ``observe(g)`` is one jitted ``fd_update`` on a (d, 1) factor — the
    monitor's whole per-gradient cost (the ``monitor_overhead_per_window``
    benchmark row tracks it).  Signals and the threshold policy run on the
    host at window boundaries only.
    """

    def __init__(self, d: int, cfg: MonitorConfig = MonitorConfig()):
        self.d = d
        self.cfg = cfg
        self._update = jax.jit(
            lambda st, g: fd_update(st, g[:, None], beta2=1.0))
        self._sketch = fd_init(d, cfg.ell)
        self._prev_vecs = None        # previous window's eigvecs
        self._count = 0               # gradients in the open window
        self._windows = 0
        self._eig_ema: Optional[float] = None
        self.readings: List[MonitorReading] = []

    @property
    def last_reading(self) -> Optional[MonitorReading]:
        return self.readings[-1] if self.readings else None

    @property
    def leading_eig_trajectory(self) -> List[float]:
        return [r.leading_eig for r in self.readings]

    def observe(self, g) -> Optional[MonitorReading]:
        """Fold one flattened feedback gradient into the window sketch.
        Returns a MonitorReading when this gradient closes a window."""
        g = jnp.asarray(g, jnp.float32).reshape(-1)
        if g.shape[0] != self.d:
            raise ValueError(f"gradient dim {g.shape[0]} != monitor d "
                             f"{self.d}")
        self._sketch = self._update(self._sketch, g)
        self._count += 1
        if self._count >= self.cfg.window:
            return self._close_window()
        return None

    def _close_window(self) -> MonitorReading:
        cfg = self.cfg
        leading = float(fd_leading_eigval(self._sketch))
        pressure = float(fd_pressure(self._sketch))
        drift = 0.0
        if self._prev_vecs is not None:
            drift = float(fd_subspace_angle(
                self._prev_vecs, self._sketch.eigvecs, k=cfg.top_k))

        if self._windows < cfg.warmup_windows or self._prev_vecs is None:
            decision = STEADY
        elif self._eig_ema is not None and \
                leading > cfg.spike_factor * max(self._eig_ema, 1e-30):
            decision = PAUSE
        elif drift > cfg.drift_threshold or \
                pressure > cfg.pressure_threshold:
            decision = ADAPT
        else:
            decision = STEADY

        reading = MonitorReading(window=self._windows, leading_eig=leading,
                                 pressure=pressure, drift_angle=drift,
                                 decision=decision)
        self.readings.append(reading)

        # trajectory EMA feeds the spike detector; a paused window is kept
        # OUT of the EMA so a burst of bad traffic cannot normalize itself
        if decision != PAUSE:
            self._eig_ema = leading if self._eig_ema is None else \
                cfg.eig_ema * self._eig_ema + (1.0 - cfg.eig_ema) * leading
            self._prev_vecs = self._sketch.eigvecs
        self._sketch = fd_init(self.d, cfg.ell)   # fresh per-window sketch
        self._count = 0
        self._windows += 1
        return reading
