"""Logical-axis sharding rules -> mesh PartitionSpecs.

Model code annotates activations with *logical* axes via ``constrain``;
parameters get specs from path-based rules. A thread-global ``MeshRules``
context maps logical axes to mesh axes ('pod', 'data', 'model'); with no
active context everything is a no-op, so the same model code runs unsharded
on CPU tests and fully sharded under the production mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_local = threading.local()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-compat shard_map: ``jax.shard_map`` (new jax) or
    ``jax.experimental.shard_map`` (<= 0.4.x, where ``check_vma`` is spelled
    ``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as sm_old
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


# logical axis -> mesh axis (tuple = sharded over multiple mesh axes)
DEFAULT_LOGICAL_RULES = {
    "batch": ("pod", "data"),     # DP over pod + data
    "fsdp": "data",               # param row sharding (ZeRO-3 style)
    "tensor": "model",            # TP
    "vocab": "model",
    "experts": "model",           # EP
    "kv_seq": "model",            # decode-cache sequence sharding (SP)
    "seq": None,                  # training seq unsharded by default
    "embed": None,                # residual d_model dim (activations)
    "heads": "model",
    "stack": None,                # scan-over-layers stack dim
    # optimizer per-block state: leading blocks dim tiled model-major so
    # EP-sharded expert gradients re-layout locally (EXPERIMENTS.md §Perf,
    # kimi iteration 3)
    "opt_blocks": ("model", "data"),
}


@dataclasses.dataclass
class MeshRules:
    mesh: Mesh
    rules: dict

    def axis(self, logical: Optional[str]):
        if logical is None:
            return None
        mapped = self.rules.get(logical, None)
        if mapped is None:
            return None
        axes = mapped if isinstance(mapped, tuple) else (mapped,)
        present = tuple(a for a in axes if a in self.mesh.axis_names)
        if not present:
            return None
        return present if len(present) > 1 else present[0]

    def spec(self, *logical_axes) -> P:
        return P(*(self.axis(a) for a in logical_axes))

    def sharding(self, *logical_axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical_axes))


def current() -> Optional[MeshRules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    prev = current()
    _local.rules = MeshRules(mesh=mesh, rules={**DEFAULT_LOGICAL_RULES,
                                               **(rules or {})})
    try:
        with mesh:
            yield _local.rules
    finally:
        _local.rules = prev


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical axes; no-op without a mesh.

    Shape-aware: axes whose mesh extent does not divide the dim are dropped
    (padded shardings force GSPMD into full-logits all-gathers — see
    EXPERIMENTS.md §Perf, qwen2.5-32b iteration 1)."""
    r = current()
    if r is None:
        return x
    sh = enforce_divisible(r.sharding(*logical_axes), x.shape)
    return jax.lax.with_sharding_constraint(x, sh)


def dp_axis_names(mesh: Mesh) -> tuple:
    """Mesh axes the ``batch`` logical axis maps onto — the data-parallel
    axes a gradient mean / sketch merge reduces over (train/trainer.py,
    distributed/reduce.py)."""
    mapped = DEFAULT_LOGICAL_RULES["batch"]
    axes = mapped if isinstance(mapped, tuple) else (mapped,)
    return tuple(a for a in axes if a in mesh.axis_names)


def axis_extent(logical: str) -> int:
    """Product of mesh-axis sizes a logical axis maps to (1 = unmapped)."""
    r = current()
    if r is None:
        return 1
    ax = r.axis(logical)
    if ax is None:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axes:
        n *= r.mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# Parameter specs by path. Paths are '/'-joined pytree keys.
# Patterns are tried in order; first match wins. Leading stack dims
# (scan-over-layers, experts already named) are handled by the rule arity:
# specs are right-aligned to the param rank, left-padded with None.
PARAM_RULES: Sequence[tuple[str, tuple]] = (
    (r".*embed.*", ("vocab", "fsdp")),
    (r".*lm_head.*", ("fsdp", "vocab")),
    (r".*experts.*/w_(gate|up)", ("experts", "fsdp", None)),
    (r".*experts.*/w_down", ("experts", None, "fsdp")),
    (r".*router.*", ("fsdp", None)),
    (r".*/(wq|wk|wv|wqkv)$", ("fsdp", "tensor")),
    (r".*/(wo)$", ("tensor", "fsdp")),
    (r".*/(bq|bk|bv)$", ("tensor",)),
    (r".*/w_(gate|up)$", ("fsdp", "tensor")),
    (r".*/w_down$", ("tensor", "fsdp")),
    (r".*/in_proj$", ("fsdp", "tensor")),
    (r".*/out_proj$", ("tensor", "fsdp")),
    (r".*/conv_w$", (None, "tensor")),
    (r".*/(A_log|dt_bias|ssm_D|gate_norm)$", ("tensor",)),
    (r".*norm.*", (None,)),
    (r".*", (None,)),
)


def param_spec(path: str, rank: int, rules: MeshRules) -> P:
    for pat, logical in PARAM_RULES:
        if re.fullmatch(pat, path):
            axes = tuple(logical)
            if len(axes) < rank:          # left-pad stack dims
                axes = (None,) * (rank - len(axes)) + axes
            axes = axes[-rank:] if rank else ()
            return rules.spec(*axes)
    return P()


def enforce_divisible(sharding: NamedSharding, shape) -> NamedSharding:
    """Drop spec axes whose mesh extent does not divide the dim size.
    (pjit requires divisible input shardings; vocab sizes like 50280 are not
    multiples of 16 — production would pad, the dry-run baseline relaxes.)"""
    mesh = sharding.mesh
    spec = sharding.spec
    new = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            new.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        new.append(entry if shape[i] % total == 0 else None)
    return NamedSharding(mesh, P(*new))


def tree_param_specs(params, rules: MeshRules):
    """Pytree of PartitionSpecs matching a params pytree."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat[0]:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        specs.append(param_spec(name, leaf.ndim, rules))
    return jax.tree_util.tree_unflatten(flat[1], specs)


def tree_param_shardings(params, rules: MeshRules):
    specs = tree_param_specs(params, rules)
    return jax.tree.map(
        lambda s, leaf: enforce_divisible(NamedSharding(rules.mesh, s),
                                          leaf.shape),
        specs, params, is_leaf=lambda x: isinstance(x, P))


def blocks_sharding(rules: MeshRules, leaf) -> NamedSharding:
    """Sharding for a pooled optimizer-state stack (core/pool.py): leading
    blocks dim over the model-major ``opt_blocks`` tiling (when divisible;
    falls back to data-only fsdp, then replicated).

    The pooled leading dim spans every same-shaped block in the model — not
    one parameter's tiles — so with shape-grouped pools the ('model', 'data')
    product almost always divides it and FD refresh shards over the whole
    mesh.  Model-major matches the expert-major flattening of EP-sharded
    parameters, keeping the grad->block re-layout local (EXPERIMENTS.md
    §Perf, kimi iteration 3).

    Quantized pools (core/quantize.py) route both halves of a
    ``QuantizedPool`` through here: the int8 ``values`` stack
    ``(N, bs_m, bs_n)`` and its fp32 ``scale`` stack ``(N, 1, ..., 1)``
    share the same leading ``N``, so they land on the same leading-dim
    sharding decision and every device holds the scales for exactly the
    blocks it owns (dequantize is shard-local, no gather)."""
    ndim = leaf.ndim
    if not ndim:
        return NamedSharding(rules.mesh, P())
    for axis in ("opt_blocks", "fsdp"):
        spec = rules.spec(*([axis] + [None] * (ndim - 1)))
        sh = enforce_divisible(NamedSharding(rules.mesh, spec), leaf.shape)
        if sh.spec[0] is not None:
            return sh
    return NamedSharding(rules.mesh, P(*([None] * ndim)))
