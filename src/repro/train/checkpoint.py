"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic.

Format: a directory per step — one .npy per flattened leaf (gathered to host)
+ manifest.json (treedef paths, step, data cursor). Writes go to
``<dir>/tmp-<step>`` and are atomically renamed to ``<dir>/step-<step>`` —
a crash mid-write never corrupts the latest checkpoint. ``AsyncCheckpointer``
snapshots arrays to host memory synchronously (cheap) and does the disk I/O
on a background thread, overlapping with subsequent train steps.

Restore is mesh-agnostic: leaves are loaded on host and ``device_put`` with
whatever shardings the *current* mesh dictates — so a job can restart on a
different pod count (elastic re-mesh, train/elastic.py).

Manifests walk ``StateMeta`` (core/api.py): every leaf record carries the
role/blocked annotation of its ``Tagged`` wrapper (null for plain leaves),
and restore cross-checks recorded roles against the template's metadata —
a structural mismatch between optimizer variants fails loudly instead of
silently loading a momentum buffer into a second-moment slot.

Format migration: checkpoints written before the block-pool engine
(core/pool.py) stored per-leaf block stacks at
``...::leaves::<j>::stats::<...>``; the pooled layout packs those stacks
into shape-grouped pools at ``...::pools::<bs_m>x<bs_n>::<...>``.
``restore`` detects the old layout and re-packs it on the fly (leaf order ==
pool pack order, so migration is pure concatenation) — no re-warmup of
second-moment state on upgrade.

Quantized-state migration: pools stored under a different
``second_moment_dtype`` (core/quantize.py) than the restore template are
converted on the fly — an fp32/bf16 checkpoint restores into an int8 run by
quantizing each stack (deterministic round-to-nearest: restores are
reproducible), and an int8 checkpoint restores into an fp32/bf16 run by
dequantizing ``values * scale``.  Same-structure dtype changes (fp32 <->
bf16) are a plain cast in the main restore path, which also reinterprets
bfloat16 leaves that ``np.load`` hands back as raw void (``|V2``) arrays.

Fixed-rank migration: checkpoints written before the rank-budget allocator
(core/sketchy.RankBudget) carry no per-block active-rank vectors
(``...::.k::.value``).  Restoring one into a budgeted template fills those
vectors from the template's init-time uniform allocation; the allocator's
next reallocation then re-fits the budget to the restored spectra.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import numpy as np

import jax

from repro.core import api

PyTree = Any

_SEP = "::"


def _flatten_with_names(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    named = []
    for path, leaf in flat[0]:
        name = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path)
        named.append((name or "leaf", leaf))
    return named, flat[1]


def _meta_records(tree: PyTree):
    """Per-leaf StateMeta dicts (or None), aligned with the full flatten."""
    out = []
    for meta, _ in api.leaves_with_meta(tree):
        if meta is None:
            out.append(None)
        else:
            out.append({"role": meta.role, "blocked": meta.blocked,
                        "param_index": meta.param_index})
    return out


def _transient_flags(tree: PyTree):
    """Per-leaf ``StateMeta.transient`` booleans, aligned with the full
    flatten.  Transient leaves (the async-refresh pending double buffer,
    core/api.py) are derived state: dropped on save, zero-filled on restore
    — so manifests are identical across ``refresh_mode`` and checkpoints
    move freely between inline and async runs."""
    return [meta is not None and getattr(meta, "transient", False)
            for meta, _ in api.leaves_with_meta(tree)]


def save(directory: str, step: int, state: PyTree, *,
         extra: Optional[dict] = None) -> str:
    """Synchronous atomic save. Returns the final path.  Transient leaves
    (pending refresh double buffer) are not written — a checkpoint from an
    async run is byte-identical in structure to an inline run's."""
    named, _ = _flatten_with_names(state)
    metas = _meta_records(state)
    trans = _transient_flags(state)
    named = [nl for nl, t in zip(named, trans) if not t]
    metas = [m for m, t in zip(metas, trans) if not t]
    tmp = os.path.join(directory, f"tmp-{step}")
    final = os.path.join(directory, f"step-{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, ((name, leaf), meta) in enumerate(zip(named, metas)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf-{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"name": name, "file": fname,
                                   "dtype": str(arr.dtype),
                                   "shape": list(arr.shape),
                                   "meta": meta})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(directory, keep=3)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step-{s}"), ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step-"):
            try:
                out.append(int(d.split("-", 1)[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def _load_rec(path: str, rec: dict) -> np.ndarray:
    """Load one manifest record, restoring dtypes ``np.save`` round-trips as
    raw void bytes (bfloat16 -> ``|V2``) via the recorded dtype string."""
    arr = np.load(os.path.join(path, rec["file"]))
    if arr.dtype.kind == "V":
        arr = arr.view(np.dtype(rec["dtype"]))
    return arr


def _is_floatlike(dt: np.dtype) -> bool:
    return dt.kind == "f" or dt.name == "bfloat16"


def _cast_to_template(arr: np.ndarray, tmpl) -> np.ndarray:
    """Cast a loaded float leaf onto the template's float dtype (fp32 <->
    bf16 restores); non-float or matching dtypes pass through untouched."""
    tdt = np.dtype(tmpl.dtype)
    if arr.dtype != tdt and _is_floatlike(arr.dtype) and _is_floatlike(tdt):
        return np.asarray(jax.numpy.asarray(arr).astype(tdt))
    return arr


_PRE_POOL_STATS = re.compile(r"^(.*)\.leaves::(\d+)::\.stats::(.+)$")
_POOL_LEAF = re.compile(r"^(.*)\.pools::(\d+x\d+)::(.+)$")

# Tagged leaf path suffixes for the quantized-pool container
# (core/quantize.py): an fp32/bf16 stack lives at ``<base>::.value``; its
# int8 form splits into ``<base>::.values::.value`` + ``<base>::.scale::.value``.
_QP_VALUES = "::.values::.value"
_QP_SCALE = "::.scale::.value"
_TAGGED = "::.value"
# Per-block active-rank vector of the rank-budget allocator
# (core/sketchy.BudgetedSketchStats.k).  Fixed-rank checkpoints predate it;
# the migration shims fill it from the template's init-time uniform
# allocation instead of failing the restore.
_ACTIVE_RANK = "::.k::.value"


def _migrate_pre_pool(path: str, manifest: dict, named: list,
                      metas: list) -> Optional[list]:
    """Re-pack a pre-pool (per-leaf engine) checkpoint into the pooled
    template layout.  Returns np arrays aligned with the template flatten
    order, or None when the manifest is not the old layout.

    Old blocked stacks live at ``<prefix>leaves::<j>::stats::<suffix>``; the
    pooled template wants ``<prefix>pools::<KEY>::<suffix>`` whose leading
    dim concatenates the member leaves' stacks in leaf order — exactly the
    canonical pack order of core/pool.py.  Leaf->group membership is
    recovered structurally: leaf j belongs to the (unique) group whose
    per-block stat shapes match on every suffix.
    """
    recs = {r["name"]: r for r in manifest["leaves"]}
    pool_targets = [(i, _POOL_LEAF.match(name))
                    for i, (name, _) in enumerate(named)]
    pool_targets = [(i, m) for i, m in pool_targets if m]
    has_old = any(_PRE_POOL_STATS.match(r["name"])
                  and (r.get("meta") or {}).get("blocked")
                  for r in manifest["leaves"])
    if not pool_targets or not has_old:
        return None

    # prefix -> leaf j -> {suffix: record}; only blocked (block-stack) stats.
    old: dict = {}
    for r in manifest["leaves"]:
        m = _PRE_POOL_STATS.match(r["name"])
        if not m or not (r.get("meta") or {}).get("blocked"):
            continue
        prefix, j, suffix = m.group(1), int(m.group(2)), m.group(3)
        old.setdefault(prefix, {}).setdefault(j, {})[suffix] = r

    # prefix -> KEY -> {suffix: (template index, shape)}
    want: dict = {}
    for i, m in pool_targets:
        prefix, key, suffix = m.group(1), m.group(2), m.group(3)
        want.setdefault(prefix, {}).setdefault(key, {})[suffix] = \
            (i, tuple(named[i][1].shape))

    out: dict = {}          # template index -> np array
    consumed: set = set()   # old record names folded into pools
    for prefix, groups in want.items():
        members = old.get(prefix, {})
        assign: dict = {key: [] for key in groups}
        for j in sorted(members):
            matches = [key for key, suffixes in groups.items()
                       if set(suffixes) == set(members[j]) and all(
                           tuple(members[j][sfx]["shape"])[1:] == shp[1:]
                           for sfx, (_, shp) in suffixes.items())]
            if len(matches) != 1:
                raise ValueError(
                    f"pre-pool migration: leaf {prefix}leaves::{j} matches "
                    f"{len(matches)} shape groups — cannot regroup")
            assign[matches[0]].append(j)
        for key, leaf_ids in assign.items():
            for sfx, (i, shp) in groups[key].items():
                parts = [_load_rec(path, members[j][sfx])
                         for j in leaf_ids]
                consumed.update(members[j][sfx]["name"] for j in leaf_ids)
                arr = parts[0] if len(parts) == 1 \
                    else np.concatenate(parts, axis=0)
                if tuple(arr.shape) != shp:
                    raise ValueError(
                        f"pre-pool migration: pool {prefix}pools::{key}::"
                        f"{sfx} expects {shp}, regrouped stacks give "
                        f"{tuple(arr.shape)}")
                out[i] = arr

    pooled_idx = {i for i, _ in pool_targets}
    leaves = []
    for i, ((name, tmpl), meta) in enumerate(zip(named, metas)):
        if i in pooled_idx:
            leaves.append(out[i])
            continue
        rec = recs.get(name)
        if rec is None:
            raise ValueError(
                f"pre-pool migration: template leaf {name!r} missing from "
                "checkpoint")
        rec_meta = rec.get("meta")
        if meta is not None and rec_meta is not None \
                and rec_meta["role"] != meta["role"]:
            raise ValueError(
                f"state-role mismatch at {name}: checkpoint has "
                f"{rec_meta['role']!r}, template expects {meta['role']!r}")
        consumed.add(name)
        leaves.append(_load_rec(path, rec))
    leftover = set(recs) - consumed
    if leftover:
        raise ValueError(
            f"pre-pool migration: {len(leftover)} checkpoint leaves were not "
            f"consumed (e.g. {sorted(leftover)[:3]}) — incompatible states")
    return leaves


def _migrate_quantized(path: str, manifest: dict, named: list,
                       metas: list) -> Optional[list]:
    """Convert pool stacks across ``second_moment_dtype`` layouts.

    Handles both directions of the int8 structural change: a template leaf
    pair ``<base>::.values::.value`` / ``<base>::.scale::.value`` fed from a
    checkpointed ``<base>::.value`` stack (quantize on load, deterministic
    rounding), and a template ``<base>::.value`` fed from a checkpointed
    values/scale pair (dequantize on load).  Leaves whose names match
    exactly load as usual (with fp32<->bf16 casting).  Returns arrays
    aligned with the template flatten order, or ``None`` when no
    quantization-layout rename is involved (so unrelated mismatches keep
    their original error messages).
    """
    from repro.core import quantize

    recs = {r["name"]: r for r in manifest["leaves"]}
    names = [n for n, _ in named]
    involved = False
    for name in names:
        if name in recs:
            continue
        if name.endswith(_QP_VALUES) or name.endswith(_QP_SCALE):
            base = name[:-len(_QP_VALUES)] if name.endswith(_QP_VALUES) \
                else name[:-len(_QP_SCALE)]
            involved |= (base + _TAGGED) in recs
        elif name.endswith(_TAGGED):
            base = name[:-len(_TAGGED)]
            involved |= (base + _QP_VALUES) in recs
    if not involved:
        return None

    def check_role(name, meta, rec):
        rec_meta = rec.get("meta")
        if meta is not None and rec_meta is not None \
                and rec_meta["role"] != meta["role"]:
            raise ValueError(
                f"state-role mismatch at {name}: checkpoint has "
                f"{rec_meta['role']!r}, template expects {meta['role']!r}")

    dequant_cache: dict = {}    # base -> dequantized fp32 np array
    quant_cache: dict = {}      # base -> (values int8, scale fp32) np arrays
    # template scale shapes drive the absmax reduction: per-block scales
    # (N, 1, ..., 1) for pool stacks, whole-array (1, ..., 1) scales for
    # the diag-fallback leaf accumulators (quantize.quantize_leaf_state)
    scale_shapes = {n[:-len(_QP_SCALE)]: tuple(np.shape(t))
                    for n, t in named if n.endswith(_QP_SCALE)}

    def quantized(base, name, meta):
        if base not in quant_cache:
            rec = recs[base + _TAGGED]
            check_role(name, meta, rec)
            src = np.asarray(jax.numpy.asarray(_load_rec(path, rec))
                             .astype(jax.numpy.float32))
            sshape = scale_shapes.get(
                base, (np.shape(src)[:1] or (1,)) + (1,) * (src.ndim - 1))
            qp = quantize.quantize_like(jax.numpy.asarray(src), sshape)
            quant_cache[base] = (np.asarray(qp.values), np.asarray(qp.scale))
            consumed.add(rec["name"])
        return quant_cache[base]

    consumed: set = set()
    leaves = []
    for (name, tmpl), meta in zip(named, metas):
        if name in recs:
            rec = recs[name]
            check_role(name, meta, rec)
            consumed.add(name)
            leaves.append(_cast_to_template(_load_rec(path, rec), tmpl))
            continue
        if name.endswith(_QP_VALUES):
            leaves.append(quantized(name[:-len(_QP_VALUES)], name, meta)[0])
            continue
        if name.endswith(_QP_SCALE):
            leaves.append(quantized(name[:-len(_QP_SCALE)], name, meta)[1])
            continue
        if name.endswith(_TAGGED):
            base = name[:-len(_TAGGED)]
            vrec = recs.get(base + _QP_VALUES)
            srec = recs.get(base + _QP_SCALE)
            if vrec is not None and srec is not None:
                check_role(name, meta, vrec)
                if base not in dequant_cache:
                    v = _load_rec(path, vrec).astype(np.float32)
                    dequant_cache[base] = v * _load_rec(path, srec)
                consumed.update((vrec["name"], srec["name"]))
                leaves.append(_cast_to_template(dequant_cache[base], tmpl))
                continue
        if name.endswith(_ACTIVE_RANK) and (meta or {}).get("role") == "count":
            # dtype change combined with a fixed-rank (pre-budget)
            # checkpoint: keep the template's init-time allocation
            leaves.append(np.asarray(jax.device_get(tmpl)))
            continue
        raise ValueError(
            f"quantized-state migration: template leaf {name!r} has no "
            "source in the checkpoint (neither an exact match nor a "
            "convertible quantized/unquantized counterpart)")
    leftover = set(recs) - consumed
    if leftover:
        raise ValueError(
            f"quantized-state migration: {len(leftover)} checkpoint leaves "
            f"were not consumed (e.g. {sorted(leftover)[:3]}) — "
            "incompatible states")
    return leaves


def _migrate_fixed_rank(path: str, manifest: dict, named: list,
                        metas: list) -> Optional[list]:
    """Restore a fixed-rank (pre-rank-budget) checkpoint into a budgeted
    template.  Such checkpoints carry no per-block active-rank vectors
    (``<base>::.k::.value``, role ``"count"``); every other template leaf
    must match the checkpoint exactly (with the usual fp32<->bf16 cast).
    The missing k leaves keep their template values — the init-time uniform
    allocation — and the allocator's next reallocation re-fits them to the
    restored spectra.  Returns arrays aligned with the template flatten
    order, or ``None`` when no k leaf is missing.
    """
    recs = {r["name"]: r for r in manifest["leaves"]}
    if not any(n not in recs and n.endswith(_ACTIVE_RANK)
               and (m or {}).get("role") == "count"
               for (n, _), m in zip(named, metas)):
        return None

    consumed: set = set()
    leaves = []
    for (name, tmpl), meta in zip(named, metas):
        if name not in recs and name.endswith(_ACTIVE_RANK) \
                and (meta or {}).get("role") == "count":
            leaves.append(np.asarray(jax.device_get(tmpl)))
            continue
        rec = recs.get(name)
        if rec is None:
            raise ValueError(
                f"fixed-rank migration: template leaf {name!r} missing from "
                "checkpoint")
        rec_meta = rec.get("meta")
        if meta is not None and rec_meta is not None \
                and rec_meta["role"] != meta["role"]:
            raise ValueError(
                f"state-role mismatch at {name}: checkpoint has "
                f"{rec_meta['role']!r}, template expects {meta['role']!r}")
        consumed.add(name)
        leaves.append(_cast_to_template(_load_rec(path, rec), tmpl))
    leftover = set(recs) - consumed
    if leftover:
        raise ValueError(
            f"fixed-rank migration: {len(leftover)} checkpoint leaves were "
            f"not consumed (e.g. {sorted(leftover)[:3]}) — incompatible "
            "states")
    return leaves


def restore(directory: str, template: PyTree, *, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> tuple[PyTree, int, dict]:
    """Load into the structure of ``template``; reshard onto ``shardings``
    (same treedef) if given. Returns (state, step, extra).

    Transient template leaves are never looked up in the checkpoint (save
    dropped them): they restore as zeros.  For the async pending slot this
    zeroes the ``valid`` flag, so the first post-restore commit is a no-op
    and the pipeline re-primes itself on the normal refresh schedule —
    inline checkpoints restore into async runs (and vice versa) unchanged.
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step-{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    named_all, treedef = _flatten_with_names(template)
    trans = _transient_flags(template)
    sh_all = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "addressable_devices"))
        if shardings is not None else [None] * len(named_all))
    named = [nl for nl, t in zip(named_all, trans) if not t]
    metas = [m for m, t in zip(_meta_records(template), trans) if not t]

    def assemble(kept_arrays):
        """Interleave loaded leaves with zero-filled transient slots and
        unflatten, device_putting onto the full sharding assignment."""
        it = iter(kept_arrays)
        leaves = []
        for (name, tmpl), t, sh in zip(named_all, trans, sh_all):
            arr = np.zeros(tuple(np.shape(tmpl)), np.dtype(tmpl.dtype)) \
                if t else next(it)
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
        return (jax.tree_util.tree_unflatten(treedef, leaves), step,
                manifest.get("extra", {}))

    if [n for n, _ in named] != [r["name"] for r in manifest["leaves"]]:
        migrated = _migrate_pre_pool(path, manifest, named, metas)
        if migrated is None:
            migrated = _migrate_quantized(path, manifest, named, metas)
        if migrated is None:
            migrated = _migrate_fixed_rank(path, manifest, named, metas)
        if migrated is not None:
            return assemble(migrated)
    if len(named) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, template has "
            f"{len(named)} — incompatible structures")

    loaded = []
    for (name, tmpl), meta, rec in zip(named, metas, manifest["leaves"]):
        if name != rec["name"]:
            raise ValueError(f"leaf mismatch: {name} vs {rec['name']}")
        rec_meta = rec.get("meta")
        if meta is not None and rec_meta is not None \
                and rec_meta["role"] != meta["role"]:
            raise ValueError(
                f"state-role mismatch at {name}: checkpoint has "
                f"{rec_meta['role']!r}, template expects {meta['role']!r}")
        loaded.append(_cast_to_template(_load_rec(path, rec), tmpl))
    return assemble(loaded)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk on a worker thread."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, state: PyTree, *, extra: Optional[dict] = None):
        self.wait()  # one outstanding write at a time
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save(self.directory, step, snapshot, extra=extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
