"""Gradient compression for the data-parallel all-reduce.

int8 quantize -> psum -> dequantize inside shard_map over the dp axes:
each tensor is scaled by its (all-reduduced) absmax, rounded stochastically
to int8, summed in int32, and rescaled — 4x (fp32) / 2x (bf16) reduction in
all-reduce bytes at <0.4% relative error (tests/test_compression.py).

This is the paper-adjacent distributed-optimization trick (Sketchy shrinks
optimizer *state*; this shrinks optimizer *traffic*), exposed as an optional
wrapper around the gradient computation for pure-DP (non-FSDP) runs where
gradients are all-reduced rather than reduce-scattered by GSPMD.  The
scale/round core (absmax -> int8 range, stochastic rounding) is shared with
the pool-level second-moment quantization in ``core/quantize.py``.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import quantize
from repro.sharding.rules import shard_map

PyTree = Any


def quantized_psum(g: jnp.ndarray, axes: Sequence[str], key) -> jnp.ndarray:
    """int8 quantize -> int32 psum -> rescaled mean over bound mesh axes.

    Public: the shared int8 transport primitive — the gradient all-reduce
    here and the sketch-merge wire (distributed/sketch_merge.py) both ride
    the same ``core/quantize.py`` scale/round core.
    """
    g32 = g.astype(jnp.float32)
    absmax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axes[0])
    for a in axes[1:]:
        absmax = jax.lax.pmax(absmax, a)
    # shared core (core/quantize.py): absmax -> int8 scale, stochastic
    # rounding keeps the compressed all-reduce unbiased
    scale = quantize.int8_scale(absmax)
    q = quantize.round_int8(g32 / scale, key)
    summed = q.astype(jnp.int32)
    for a in axes:
        summed = jax.lax.psum(summed, a)
    # axis extent without jax.lax.axis_size (absent in jax <= 0.4.x)
    n = jax.lax.psum(1, axes[0])
    for a in axes[1:]:
        n *= jax.lax.psum(1, a)
    return (summed.astype(jnp.float32) * scale / n).astype(g.dtype)


def compressed_mean_grads(grads: PyTree, mesh: Mesh,
                          dp_axes: Sequence[str] = ("data",),
                          seed: int = 0) -> PyTree:
    """Average per-device gradient shards over dp axes with int8 transport.

    grads must be replicated over ``dp_axes`` *logically* (each device holds
    its local microbatch gradient); returns the dp-mean.
    """
    axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    if not axes:
        return grads

    flat, treedef = jax.tree.flatten(grads)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=tuple(P() for _ in flat), out_specs=tuple(P() for _ in flat),
        check_vma=False)
    def reduce_all(*leaves):
        key = jax.random.PRNGKey(seed)
        out = []
        for i, g in enumerate(leaves):
            out.append(quantized_psum(g, axes, jax.random.fold_in(key, i)))
        return tuple(out)

    return jax.tree.unflatten(treedef, list(reduce_all(*flat)))
