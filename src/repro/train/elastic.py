"""Elastic scaling + straggler mitigation.

Elastic re-mesh: on failure/resize, rebuild a mesh from the devices that are
actually alive and reshard the checkpointed state onto it. Checkpoints are
mesh-agnostic (train/checkpoint.py), so the only work is recomputing the
sharding trees for the new mesh and ``device_put``-ing on restore. The mesh
chooser keeps the model axis fixed (TP degree is architectural) and absorbs
device loss in the data axis — batch is rebalanced via the data pipeline's
``num_hosts`` arg.

Straggler mitigation: ``StragglerMonitor`` tracks per-step wall-times with a
robust (median + MAD) detector; steps beyond ``k`` sigmas are logged and
counted, and the trainer can skip a lagging host's shard by reassigning its
data range (deterministic pipeline ⇒ any host can generate any shard).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

import jax


@dataclasses.dataclass
class ElasticPlan:
    mesh_shape: tuple
    axis_names: tuple
    global_batch: int
    note: str = ""


def plan_mesh(num_devices: int, *, model_parallel: int,
              target_global_batch: int, pods: int = 1) -> ElasticPlan:
    """Largest (pod, data, model) mesh that fits the surviving devices.

    model_parallel is fixed (weights are laid out for it); data-parallel
    degree absorbs the loss. Global batch stays constant (per-device batch
    grows) unless it stops dividing, in which case it is rounded down to the
    nearest multiple of the new dp degree.
    """
    per_pod = num_devices // pods
    dp = per_pod // model_parallel
    if dp < 1:
        raise ValueError(
            f"{num_devices} devices cannot host model_parallel={model_parallel}")
    batch = target_global_batch
    total_dp = dp * pods
    if batch % total_dp:
        batch = max((batch // total_dp), 1) * total_dp
    if pods > 1:
        return ElasticPlan((pods, dp, model_parallel),
                           ("pod", "data", "model"), batch,
                           note=f"elastic: {num_devices} devices -> "
                                f"{pods}x{dp}x{model_parallel}")
    return ElasticPlan((dp, model_parallel), ("data", "model"), batch,
                       note=f"elastic: {num_devices} devices -> "
                            f"{dp}x{model_parallel}")


def remesh(plan: ElasticPlan, devices: Optional[Sequence] = None):
    devices = list(devices if devices is not None else jax.devices())
    n = int(np.prod(plan.mesh_shape))
    grid = np.asarray(devices[:n]).reshape(plan.mesh_shape)
    return jax.sharding.Mesh(grid, plan.axis_names)


def remesh_opt_state(opt_state, params, mesh, rules: Optional[dict] = None):
    """Re-balance live training state onto a new mesh.

    Restore used to route every leaf through its owning parameter's
    sharding, which left the packed pool stacks (core/pool.py) replicated
    after a mesh change.  This routes them through the metadata-driven
    sharding assignment instead (``trainer.train_state_shardings``), so the
    pooled leading ``opt_blocks`` dim is re-sharded directly over the new
    mesh — one ``device_put`` re-balances every same-shaped block in the
    model across the surviving devices.

    Returns ``(params, opt_state)`` placed on ``mesh``.
    """
    from repro.sharding import rules as rules_lib
    from repro.train import trainer
    mr = rules_lib.MeshRules(mesh=mesh,
                             rules={**rules_lib.DEFAULT_LOGICAL_RULES,
                                    **(rules or {})})
    param_sh = rules_lib.tree_param_shardings(params, mr)
    state_sh = trainer.train_state_shardings(opt_state, params, mr)
    return (jax.device_put(params, param_sh),
            jax.device_put(opt_state, state_sh))


def merge_sketches_on_shrink(states: Sequence):
    """Fold per-shard sketch statistics into one on mesh shrink.

    Under ``stats_reduction="sharded"`` the shards' sketches only coincide
    at refresh boundaries; when devices leave mid-window, each departing
    shard's last pooled ``FDState`` stacks are tree-merged into the
    survivors' (exact ``fd_merge_batched``, no wire) so no observed
    curvature is dropped.  ``states`` is a sequence of structurally equal
    stats pytrees (e.g. ``PrecondState.pools`` values or
    ``SketchyBlockStats``); FD stacks merge via the mergeable-sketch
    primitive, everything else must already agree and passes through from
    the first shard.
    """
    from repro.core import api
    from repro.core.fd import FDState
    from repro.distributed import sketch_merge
    states = list(states)
    if len(states) == 1:
        return states[0]

    is_fd = lambda x: isinstance(x, FDState)
    flat0, treedef = jax.tree.flatten(states[0], is_leaf=is_fd)
    flats = [treedef.flatten_up_to(s) for s in states]
    out = []
    for i, x in enumerate(flat0):
        if is_fd(x):
            merged = sketch_merge.merge_stack_states(
                [FDState(*api.untag(list(f[i]))) for f in flats])
            out.append(api.tag_like(x, merged))
        else:
            out.append(x)
    return jax.tree.unflatten(treedef, out)


class StragglerMonitor:
    """Robust per-step latency anomaly detector (median + MAD)."""

    def __init__(self, window: int = 50, k: float = 6.0):
        self.window = window
        self.k = k
        self.times: List[float] = []
        self.flagged = 0
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> Optional[float]:
        """Returns the step time; increments ``flagged`` when anomalous."""
        assert self._t0 is not None, "start() not called"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        hist = self.times[-self.window:]
        if len(hist) >= 10:
            med = float(np.median(hist))
            mad = float(np.median(np.abs(np.asarray(hist) - med))) + 1e-9
            if dt > med + self.k * 1.4826 * mad:
                self.flagged += 1
        self.times.append(dt)
        return dt

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0
