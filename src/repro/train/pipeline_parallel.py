"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

Layer stack is sharded across stages (leading stacked-layer dim over the
pipe axis); microbatches stream through with jax.lax.ppermute. Forward is
written with plain collectives inside shard_map, so jax.grad differentiates
it into the standard 1F1B-ish reverse schedule automatically.

This is the optional PP substrate for very deep models / cross-pod
pipelining (the default production layout for the assigned archs is
DP+FSDP+TP — see DESIGN.md §8); correctness is covered by
tests/test_pipeline.py against the sequential reference.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.rules import shard_map


def gpipe_apply(mesh: Mesh, axis: str, layers, block_fn: Callable,
                x: jnp.ndarray, microbatches: int) -> jnp.ndarray:
    """Run ``block_fn`` over a layer stack pipelined across ``axis``.

    layers: pytree stacked on dim0 with size L, L % n_stages == 0;
    x: (B, ...) activations, B % microbatches == 0.
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % microbatches == 0, (B, microbatches)
    M = microbatches
    xm = x.reshape(M, B // M, *x.shape[1:])

    layer_specs = jax.tree.map(lambda _: P(axis), layers)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(layer_specs, P()), out_specs=P(),
        check_vma=False)
    def run(local_layers, xm):
        idx = jax.lax.axis_index(axis)
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def stage_apply(cur):
            def body(c, p):
                return block_fn(p, c), None

            out, _ = jax.lax.scan(body, cur, local_layers)
            return out

        state = jnp.zeros_like(xm[0])
        outs = jnp.zeros_like(xm)
        for t in range(M + n_stages - 1):
            recv = jax.lax.ppermute(state, axis, fwd_perm)
            inject = xm[min(t, M - 1)]
            first = (idx == 0) & (t < M)
            cur = jnp.where(first, inject, recv)
            cur = stage_apply(cur)
            state = cur
            m_idx = t - (n_stages - 1)
            if m_idx >= 0:
                write = (idx == n_stages - 1)
                outs = outs.at[m_idx].set(
                    jnp.where(write, cur, outs[m_idx]))
        # only the last stage holds real outputs; broadcast via psum
        outs = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, axis)
        return outs

    out = run(layers, xm)
    return out.reshape(B, *x.shape[1:])
