"""Training step construction + distributed state sharding.

``make_train_step`` builds the jit-able (params, opt_state, batch) ->
(params', opt_state', metrics) function: fwd+bwd (remat per layer inside the
model), optional microbatched gradient accumulation, optimizer update.

``train_state_shardings`` assigns NamedShardings to every optimizer-state
leaf by type dispatch: param-shaped leaves (momentum, grafting) inherit the
parameter sharding; Sketchy/Shampoo per-block factors shard their leading
blocks dim over the fsdp axis ('data') so second-moment state is fully
distributed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sketchy as sketchy_lib
from repro.core import shampoo as shampoo_lib
from repro.core import adam as adam_lib
from repro.core.fd import FDState
from repro.core.transform import (GradientTransformation, ScaleByScheduleState,
                                  TraceState, EmptyState, apply_updates)
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.sharding import rules as rules_lib

PyTree = Any


def make_train_step(cfg: ModelConfig, tx: GradientTransformation, *,
                    unroll: bool = False,
                    microbatches: Optional[int] = None) -> Callable:
    def loss_of(params, batch):
        return model_lib.loss_fn(cfg, params, batch, unroll=unroll)

    def train_step(params, opt_state, batch):
        if microbatches and microbatches > 1:
            def split(key, x):
                axis = 1 if key == "positions" else 0  # positions: (3, B, S)
                assert x.shape[axis] % microbatches == 0, \
                    f"batch dim {x.shape[axis]} not divisible by {microbatches}"
                if axis == 0:
                    return x.reshape(microbatches, x.shape[0] // microbatches,
                                     *x.shape[1:])
                r = x.reshape(x.shape[0], microbatches,
                              x.shape[1] // microbatches, *x.shape[2:])
                return jnp.moveaxis(r, 1, 0)

            mb = {k: split(k, v) for k, v in batch.items()}
            zero = jax.tree.map(jnp.zeros_like, params)

            def body(acc, mbatch):
                loss, grads = jax.value_and_grad(loss_of)(params, mbatch)
                acc_loss, acc_g = acc
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_g, grads)), None

            if unroll:  # probe mode: cost_analysis must see every microbatch
                acc = (jnp.zeros([], jnp.float32), zero)
                for i in range(microbatches):
                    acc, _ = body(acc, jax.tree.map(lambda x: x[i], mb))
                loss_sum, gsum = acc
            else:
                (loss_sum, gsum), _ = jax.lax.scan(
                    body, (jnp.zeros([], jnp.float32), zero), mb)
            inv = 1.0 / microbatches
            loss = loss_sum * inv
            grads = jax.tree.map(lambda g: g * inv, gsum)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        updates, new_opt_state = tx.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_params, new_opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


# ---------------------------------------------------------------------------
# Sharding assignment for optimizer state


def _blocks_sharding(rules: rules_lib.MeshRules, leaf) -> NamedSharding:
    """Leading blocks dim over model-major (model, data) tiling (when
    divisible; falls back to data-only, then replicated). Model-major matches
    the expert-major flattening of EP-sharded parameters, keeping the
    grad->block re-layout local."""
    ndim = leaf.ndim
    if not ndim:
        return NamedSharding(rules.mesh, P())
    for axis in ("opt_blocks", "fsdp"):
        spec = rules.spec(*([axis] + [None] * (ndim - 1)))
        sh = rules_lib.enforce_divisible(NamedSharding(rules.mesh, spec),
                                         leaf.shape)
        if sh.spec[0] is not None:
            return sh
    return NamedSharding(rules.mesh, P(*([None] * ndim)))


def train_state_shardings(opt_state: PyTree, params: PyTree,
                          rules: rules_lib.MeshRules) -> PyTree:
    """NamedShardings for an optimizer-state pytree (works on structs)."""
    param_shardings = rules_lib.tree_param_shardings(params, rules)
    flat_param_sh = jax.tree.leaves(
        param_shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    repl = NamedSharding(rules.mesh, P())

    def fd_sharding(fs: FDState) -> FDState:
        return FDState(
            eigvecs=_blocks_sharding(rules, fs.eigvecs),
            eigvals=_blocks_sharding(rules, fs.eigvals),
            rho=_blocks_sharding(rules, fs.rho),
        )

    def leaf_states(states):
        out = []
        for st, psh in zip(states, flat_param_sh):
            if isinstance(st, sketchy_lib.MatrixLeafState):
                out.append(sketchy_lib.MatrixLeafState(
                    left=fd_sharding(st.left), right=fd_sharding(st.right),
                    graft_acc=psh))
            elif isinstance(st, sketchy_lib.DiagLeafState):
                out.append(sketchy_lib.DiagLeafState(acc=psh))
            elif isinstance(st, shampoo_lib.ShampooMatrixLeaf):
                out.append(shampoo_lib.ShampooMatrixLeaf(
                    L=_blocks_sharding(rules, st.L),
                    R=_blocks_sharding(rules, st.R),
                    PL=_blocks_sharding(rules, st.PL),
                    PR=_blocks_sharding(rules, st.PR),
                    graft_acc=psh))
            elif isinstance(st, shampoo_lib.ShampooDiagLeaf):
                out.append(shampoo_lib.ShampooDiagLeaf(acc=psh))
            else:
                raise TypeError(type(st))
        return tuple(out)

    def one(state):
        if isinstance(state, sketchy_lib.SketchyState):
            return sketchy_lib.SketchyState(count=repl,
                                            leaves=leaf_states(state.leaves))
        if isinstance(state, shampoo_lib.ShampooState):
            return shampoo_lib.ShampooState(count=repl,
                                            leaves=leaf_states(state.leaves))
        if isinstance(state, adam_lib.AdamState):
            return adam_lib.AdamState(count=repl, mu=param_shardings,
                                      nu=param_shardings)
        if isinstance(state, TraceState):
            return TraceState(momentum=param_shardings)
        if isinstance(state, ScaleByScheduleState):
            return ScaleByScheduleState(count=repl)
        if isinstance(state, EmptyState):
            return EmptyState()
        if isinstance(state, tuple) and not hasattr(state, "_fields"):
            return tuple(one(s) for s in state)
        # fallback: replicate any unknown scalar-ish state
        return jax.tree.map(lambda _: repl, state)

    return one(opt_state)
