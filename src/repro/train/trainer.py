"""Training step construction + distributed state sharding.

``make_train_step`` builds the jit-able (params, opt_state, batch) ->
(params', opt_state', metrics) function: fwd+bwd (remat per layer inside the
model), optional microbatched gradient accumulation, optimizer update.

``train_state_shardings`` assigns NamedShardings to every optimizer-state
leaf by walking the ``StateMeta`` annotations (core/api.py): param-shaped
leaves (momentum, grafting, diag accumulators) inherit the owning
parameter's sharding via ``meta.param_index``; blocked leaves are the packed
shape-group pools (core/pool.py) whose leading dim spans every same-shaped
block in the model — they shard that dim over the model-major ``opt_blocks``
axes (sharding/rules.py), so FD refresh runs data-parallel over the whole
('model', 'data') mesh.  Counts/hyperparams replicate.  No
optimizer-specific types appear here — a new Preconditioner shards
correctly for free.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import api
from repro.core.transform import GradientTransformation, apply_updates
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.sharding import rules as rules_lib

PyTree = Any


def make_train_step(cfg: ModelConfig, tx: GradientTransformation, *,
                    unroll: bool = False,
                    microbatches: Optional[int] = None,
                    data_parallel_mesh=None,
                    dp_axes: Optional[tuple] = None,
                    donate: bool = True) -> Callable:
    """Build the train step.  By default the returned function is jitted
    with ``params`` and ``opt_state`` DONATED (``donate_argnums=(0, 1)``):
    XLA reuses the input buffers for the outputs, so the step allocates no
    second copy of the model or optimizer state — in particular the async
    refresh pending slot (``EngineConfig.refresh_mode="async"``) adds zero
    steady-state copies on top of its double buffer.  Callers must not
    touch a ``params``/``opt_state`` value after passing it in (the arrays
    are deleted); pass ``donate=False`` to get the raw un-jitted callable
    (inputs preserved — reference comparisons, custom ``jax.jit`` wrappers
    with explicit shardings).

    With ``data_parallel_mesh`` the whole step runs inside the
    ``sharding/rules.shard_map`` wrapper with the batch split over
    ``dp_axes``: each shard computes gradients on its local slice, the
    chain consumes the dp-mean gradients (int-free psum — clipping,
    grafting and momentum see exactly what a replicated run sees), and the
    per-shard local gradients are handed to the engine's sharded-statistics
    path via ``distributed.reduce.local_gradients`` so
    ``stats_reduction="sharded"`` optimizers sketch their local stream and
    butterfly-merge at refresh time.  Without a mesh the behavior is the
    seed's, untouched.
    """
    def loss_of(params, batch):
        return model_lib.loss_fn(cfg, params, batch, unroll=unroll)

    def loss_and_grads(params, batch):
        if microbatches and microbatches > 1:
            def split(key, x):
                axis = 1 if key == "positions" else 0  # positions: (3, B, S)
                assert x.shape[axis] % microbatches == 0, \
                    f"batch dim {x.shape[axis]} not divisible by {microbatches}"
                if axis == 0:
                    return x.reshape(microbatches, x.shape[0] // microbatches,
                                     *x.shape[1:])
                r = x.reshape(x.shape[0], microbatches,
                              x.shape[1] // microbatches, *x.shape[2:])
                return jnp.moveaxis(r, 1, 0)

            mb = {k: split(k, v) for k, v in batch.items()}
            zero = jax.tree.map(jnp.zeros_like, params)

            def body(acc, mbatch):
                loss, grads = jax.value_and_grad(loss_of)(params, mbatch)
                acc_loss, acc_g = acc
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_g, grads)), None

            if unroll:  # probe mode: cost_analysis must see every microbatch
                acc = (jnp.zeros([], jnp.float32), zero)
                for i in range(microbatches):
                    acc, _ = body(acc, jax.tree.map(lambda x: x[i], mb))
                loss_sum, gsum = acc
            else:
                (loss_sum, gsum), _ = jax.lax.scan(
                    body, (jnp.zeros([], jnp.float32), zero), mb)
            inv = 1.0 / microbatches
            loss = loss_sum * inv
            grads = jax.tree.map(lambda g: g * inv, gsum)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        return loss, grads

    def train_step(params, opt_state, batch):
        loss, grads = loss_and_grads(params, batch)
        updates, new_opt_state = tx.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_params, new_opt_state, {"loss": loss, "grad_norm": gnorm}

    if data_parallel_mesh is None:
        if donate:
            return jax.jit(train_step, donate_argnums=(0, 1))
        return train_step

    from repro.distributed import reduce as dreduce
    mesh = data_parallel_mesh
    axes = rules_lib.dp_axis_names(mesh) if dp_axes is None else \
        tuple(a for a in dp_axes if a in mesh.axis_names)
    if not axes:
        if donate:
            return jax.jit(train_step, donate_argnums=(0, 1))
        return train_step

    def shard_body(params, opt_state, batch):
        loss_local, grads_local = loss_and_grads(params, batch)
        loss, grads = loss_local, grads_local
        for a in axes:
            loss = dreduce.pmean(loss, a)
            grads = dreduce.pmean(grads, a)
        with dreduce.local_gradients(grads_local):
            updates, new_opt_state = tx.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_params, new_opt_state, {"loss": loss, "grad_norm": gnorm}

    def sharded_train_step(params, opt_state, batch):
        def batch_spec(key):
            # positions batches on axis 1 ((3, B, S)); everything else on 0
            if key == "positions":
                return P(None, axes)
            return P(axes)
        step = rules_lib.shard_map(
            shard_body, mesh=mesh,
            in_specs=(P(), P(), {k: batch_spec(k) for k in batch}),
            out_specs=(P(), P(), P()), check_vma=False)
        return step(params, opt_state, batch)

    if donate:
        return jax.jit(sharded_train_step, donate_argnums=(0, 1))
    return sharded_train_step


# ---------------------------------------------------------------------------
# Sharding assignment for optimizer state


def train_state_shardings(opt_state: PyTree, params: PyTree,
                          rules: rules_lib.MeshRules) -> PyTree:
    """NamedShardings for an optimizer-state pytree (works on structs).

    Pure ``StateMeta`` traversal: no isinstance checks against optimizer
    leaf types anywhere."""
    param_shardings = rules_lib.tree_param_shardings(params, rules)
    flat_param_sh = jax.tree.leaves(
        param_shardings, is_leaf=lambda x: isinstance(x, NamedSharding))
    repl = NamedSharding(rules.mesh, P())

    def assign(meta: Optional[api.StateMeta], leaf) -> NamedSharding:
        if meta is None or meta.shard == "replicate" \
                or meta.role in ("count", "hyperparam"):
            return repl
        if meta.param_index is not None and meta.shard in ("auto", "param"):
            return flat_param_sh[meta.param_index]
        if meta.blocked or meta.shard == "blocks":
            return rules_lib.blocks_sharding(rules, leaf)
        return repl

    return api.map_with_meta(assign, opt_state)
