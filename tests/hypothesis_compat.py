"""Minimal stand-in for ``hypothesis`` when it is not installed.

Samples a fixed number of pseudo-random examples per test (deterministic
seed) instead of doing real property search/shrinking.  Supports exactly the
subset this suite uses: ``@settings(max_examples=, deadline=)``, ``@given``
with keyword strategies, and ``strategies.integers/lists/sampled_from``.
"""
from __future__ import annotations

import random

_DEFAULT_EXAMPLES = 20
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        return _Strategy(
            lambda r: [elements.sample(r)
                       for _ in range(r.randint(min_size, max_size))])


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # NB: no functools.wraps — pytest must see a zero-arg signature, not
        # the strategy kwargs (it would look for fixtures named after them).
        def wrapper():
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            rng = random.Random(_SEED)
            for _ in range(n):
                fn(**{k: s.sample(rng) for k, s in strats.items()})
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
