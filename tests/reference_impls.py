"""Frozen copies of the SEED (pre-unification) optimizer monoliths, plus the
PR-1 PER-LEAF engine (pre-pool).

Test fixture only: the parity tests in test_preconditioner_api.py assert the
new ``scale_by_preconditioner``-based sketchy/shampoo/adam produce
numerically identical updates to these originals, and test_pool.py pins the
pooled engine *bitwise* to ``per_leaf_scale_by_preconditioner`` (the PR-1
engine that dispatched once per parameter leaf).  Do not import from
production code.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import blocking
from repro.core.adam import AdamConfig
from repro.core.fd import FDState, fd_apply_inverse_root, fd_init, fd_update
from repro.core.shampoo import ShampooConfig
from repro.core.sketchy import SketchyConfig
from repro.core.transform import GradientTransformation


# --------------------------------------------------------------------- sketchy

class MatrixLeafState(NamedTuple):
    left: FDState
    right: FDState
    graft_acc: jnp.ndarray


class DiagLeafState(NamedTuple):
    acc: jnp.ndarray


class SketchyState(NamedTuple):
    count: jnp.ndarray
    leaves: tuple


def _graft_direction(g, acc, cfg: SketchyConfig):
    if cfg.graft == "none":
        return g, acc
    if cfg.graft == "rmsprop_normalized":
        gn = g / (jnp.linalg.norm(g) + 1e-16)
    else:
        gn = g
    acc = cfg.beta2 * acc + (1.0 - cfg.beta2) * jnp.square(gn)
    return gn * jax.lax.rsqrt(acc + cfg.graft_eps), acc


def _vmapped_fd_update(states: FDState, factors: jnp.ndarray,
                       beta2: float) -> FDState:
    return jax.vmap(lambda s, a: fd_update(s, a, beta2))(states, factors)


def _precondition_blocks(left: FDState, right: FDState, gb: jnp.ndarray,
                         cfg: SketchyConfig) -> jnp.ndarray:
    def one(ls, rs, G):
        tmp = fd_apply_inverse_root(ls, G, exponent=cfg.exponent,
                                    eps=cfg.matrix_eps)
        tmpT = fd_apply_inverse_root(rs, tmp.T, exponent=cfg.exponent,
                                     eps=cfg.matrix_eps)
        return tmpT.T

    return jax.vmap(one)(left, right, gb)


def seed_sketchy(cfg: SketchyConfig = SketchyConfig()) -> GradientTransformation:
    def init_leaf(p):
        info = blocking.analyze(p.shape, cfg.block_size)
        if info.kind == "diag":
            return DiagLeafState(acc=jnp.zeros(p.shape, cfg.state_dtype))
        S = info.num_blocks
        ell_l = min(cfg.rank, info.bs_m)
        ell_r = min(cfg.rank, info.bs_n)

        def batched_fd(d, ell):
            base = fd_init(d, ell, cfg.state_dtype)
            return FDState(*[jnp.broadcast_to(x, (S,) + x.shape) for x in base])

        return MatrixLeafState(
            left=batched_fd(info.bs_m, ell_l),
            right=batched_fd(info.bs_n, ell_r),
            graft_acc=jnp.zeros(p.shape, cfg.state_dtype),
        )

    def init_fn(params):
        leaves = tuple(init_leaf(p) for p in jax.tree.leaves(params))
        return SketchyState(count=jnp.zeros([], jnp.int32), leaves=leaves)

    def update_leaf(g, st, count):
        g32 = g.astype(jnp.float32)
        info = blocking.analyze(g.shape, cfg.block_size)
        if info.kind == "diag":
            acc = cfg.beta2 * st.acc + (1.0 - cfg.beta2) * jnp.square(g32)
            direction = g32 * jax.lax.rsqrt(acc + cfg.graft_eps)
            return direction.astype(g.dtype), DiagLeafState(acc=acc)

        gb = blocking.to_blocks(g32, info)
        gbT = jnp.swapaxes(gb, -1, -2)

        do_stats = (count % cfg.update_every) == 0

        def with_stats(s):
            return MatrixLeafState(
                left=_vmapped_fd_update(s.left, gb, cfg.beta2),
                right=_vmapped_fd_update(s.right, gbT, cfg.beta2),
                graft_acc=s.graft_acc,
            )

        st = jax.lax.cond(do_stats, with_stats, lambda s: s, st)

        pb = _precondition_blocks(st.left, st.right, gb, cfg)
        precond = blocking.from_blocks(pb, info)

        graft_dir, new_acc = _graft_direction(g32, st.graft_acc, cfg)
        if cfg.graft != "none":
            pnorm = jnp.linalg.norm(precond)
            gnorm = jnp.linalg.norm(graft_dir)
            precond = precond * (gnorm / (pnorm + 1e-16))

        use_precond = count >= cfg.start_preconditioning_step
        direction = jnp.where(use_precond, precond, graft_dir)
        return direction.astype(g.dtype), MatrixLeafState(st.left, st.right,
                                                          new_acc)

    def update_fn(updates, state, params=None):
        del params
        flat, treedef = jax.tree.flatten(updates)
        out_flat, new_leaves = [], []
        for g, st in zip(flat, state.leaves):
            d, ns = update_leaf(g, st, state.count)
            out_flat.append(d)
            new_leaves.append(ns)
        return (jax.tree.unflatten(treedef, out_flat),
                SketchyState(count=state.count + 1, leaves=tuple(new_leaves)))

    return GradientTransformation(init_fn, update_fn)


# --------------------------------------------------------------------- shampoo

class ShampooMatrixLeaf(NamedTuple):
    L: jnp.ndarray
    R: jnp.ndarray
    PL: jnp.ndarray
    PR: jnp.ndarray
    graft_acc: jnp.ndarray


class ShampooDiagLeaf(NamedTuple):
    acc: jnp.ndarray


class ShampooState(NamedTuple):
    count: jnp.ndarray
    leaves: tuple


def _inv_root(mats: jnp.ndarray, eps: float, power: float) -> jnp.ndarray:
    def one(m):
        d = m.shape[-1]
        lam, V = jnp.linalg.eigh(m + eps * jnp.eye(d, dtype=m.dtype))
        lam = jnp.maximum(lam, eps)
        return (V * jnp.power(lam, power)[None, :]) @ V.T

    return jax.vmap(one)(mats)


def seed_shampoo(cfg: ShampooConfig = ShampooConfig()) -> GradientTransformation:
    graft_cfg = SketchyConfig(beta2=cfg.beta2, graft=cfg.graft,
                              graft_eps=cfg.graft_eps)

    def init_leaf(p):
        info = blocking.analyze(p.shape, cfg.block_size)
        if info.kind == "diag":
            return ShampooDiagLeaf(acc=jnp.zeros(p.shape, cfg.state_dtype))
        S = info.num_blocks
        eye_m = jnp.eye(info.bs_m, dtype=cfg.state_dtype)
        eye_n = jnp.eye(info.bs_n, dtype=cfg.state_dtype)
        zeros = lambda d: jnp.zeros((S, d, d), cfg.state_dtype)
        return ShampooMatrixLeaf(
            L=zeros(info.bs_m), R=zeros(info.bs_n),
            PL=jnp.broadcast_to(eye_m, (S, info.bs_m, info.bs_m)),
            PR=jnp.broadcast_to(eye_n, (S, info.bs_n, info.bs_n)),
            graft_acc=jnp.zeros(p.shape, cfg.state_dtype),
        )

    def init_fn(params):
        leaves = tuple(init_leaf(p) for p in jax.tree.leaves(params))
        return ShampooState(count=jnp.zeros([], jnp.int32), leaves=leaves)

    def update_leaf(g, st, count):
        g32 = g.astype(jnp.float32)
        info = blocking.analyze(g.shape, cfg.block_size)
        if info.kind == "diag":
            acc = cfg.beta2 * st.acc + (1.0 - cfg.beta2) * jnp.square(g32)
            return (g32 * jax.lax.rsqrt(acc + cfg.graft_eps)).astype(g.dtype), \
                ShampooDiagLeaf(acc=acc)

        gb = blocking.to_blocks(g32, info)
        L = cfg.beta2 * st.L + jnp.einsum("sij,skj->sik", gb, gb)
        R = cfg.beta2 * st.R + jnp.einsum("sji,sjk->sik", gb, gb)

        def refresh(_):
            return (_inv_root(L, cfg.matrix_eps, -0.25),
                    _inv_root(R, cfg.matrix_eps, -0.25))

        do_roots = (count % cfg.root_every) == 0
        PL, PR = jax.lax.cond(do_roots, refresh, lambda _: (st.PL, st.PR),
                              None)

        pb = jnp.einsum("sij,sjk,skl->sil", PL, gb, PR)
        precond = blocking.from_blocks(pb, info)

        graft_dir, new_acc = _graft_direction(g32, st.graft_acc, graft_cfg)
        if cfg.graft != "none":
            precond = precond * (jnp.linalg.norm(graft_dir)
                                 / (jnp.linalg.norm(precond) + 1e-16))
        use_precond = count >= cfg.start_preconditioning_step
        direction = jnp.where(use_precond, precond, graft_dir)
        return direction.astype(g.dtype), ShampooMatrixLeaf(L, R, PL, PR,
                                                            new_acc)

    def update_fn(updates, state, params=None):
        del params
        flat, treedef = jax.tree.flatten(updates)
        out, leaves = [], []
        for g, st in zip(flat, state.leaves):
            d, ns = update_leaf(g, st, state.count)
            out.append(d)
            leaves.append(ns)
        return (jax.tree.unflatten(treedef, out),
                ShampooState(count=state.count + 1, leaves=tuple(leaves)))

    return GradientTransformation(init_fn, update_fn)


# ------------------------------------------------------------------------ adam

class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: object
    nu: object


def seed_adam(cfg: AdamConfig = AdamConfig()) -> GradientTransformation:
    def init_fn(params):
        zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
        return AdamState(count=jnp.zeros([], jnp.int32),
                         mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def update_fn(updates, state, params=None):
        del params
        count = state.count + 1
        mu = jax.tree.map(
            lambda m, g: cfg.beta1 * m + (1 - cfg.beta1) * g.astype(m.dtype),
            state.mu, updates)
        nu = jax.tree.map(
            lambda v, g: cfg.beta2 * v
            + (1 - cfg.beta2) * jnp.square(g.astype(v.dtype)),
            state.nu, updates)
        bc1 = 1 - cfg.beta1 ** count.astype(jnp.float32)
        bc2 = 1 - cfg.beta2 ** count.astype(jnp.float32)
        out = jax.tree.map(
            lambda m, v, g: ((m / bc1)
                             * jax.lax.rsqrt(v / bc2 + cfg.eps ** 2)
                             ).astype(g.dtype),
            mu, nu, updates)
        return out, AdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init_fn, update_fn)


# ----------------------------------------------------- PR-1 per-leaf engine
# Frozen copy of core/api.scale_by_preconditioner BEFORE the block-pool
# rebase: one vmapped update/refresh/precondition dispatch per parameter
# leaf.  Tags are stripped (state leaves are raw arrays) — the pooled engine
# must be bitwise-identical to this on directions and statistics under
# refresh_schedule="synchronized".

class PerLeafState(NamedTuple):
    count: jnp.ndarray
    leaves: tuple


class PerLeafLeaf(NamedTuple):
    stats: object
    graft: object


def per_leaf_scale_by_preconditioner(precond, cfg) -> GradientTransformation:
    """cfg is an api.EngineConfig; precond a production Preconditioner."""
    from repro.core import api

    def leaf_info(shape):
        return blocking.analyze_leaf(
            tuple(shape), cfg.block_size,
            vectors_as_columns=cfg.treat_vectors_as_columns)

    def init_leaf(p):
        info = leaf_info(p.shape)
        if info.kind == "diag":
            return PerLeafLeaf(stats=jnp.zeros(p.shape, cfg.state_dtype),
                               graft=None)
        base = api.untag(precond.init_block(info))
        S = info.num_blocks
        stats = jax.tree.map(lambda x: jnp.broadcast_to(x, (S,) + x.shape),
                             base)
        graft = (jnp.zeros(p.shape, cfg.state_dtype)
                 if cfg.graft != "none" else None)
        return PerLeafLeaf(stats=stats, graft=graft)

    def init_fn(params):
        return PerLeafState(
            count=jnp.zeros([], jnp.int32),
            leaves=tuple(init_leaf(p) for p in jax.tree.leaves(params)))

    def update_leaf(g, leaf, count):
        g32 = g.astype(jnp.float32)
        info = leaf_info(g.shape)

        if info.kind == "diag":
            acc = cfg.beta2 * leaf.stats + (1.0 - cfg.beta2) * jnp.square(g32)
            direction = g32 * jax.lax.rsqrt(acc + cfg.graft_eps)
            return (direction.astype(g.dtype),
                    PerLeafLeaf(stats=acc, graft=None))

        gb = blocking.to_blocks(g32, info)
        raw = jax.vmap(
            lambda s, G: precond.update_stats(s, G, count=count))(leaf.stats,
                                                                  gb)

        def do_refresh(s):
            return jax.vmap(
                lambda ss, G: precond.refresh(ss, G, count=count))(s, gb)

        if cfg.update_every <= 1:
            raw = do_refresh(raw)
        else:
            raw = jax.lax.cond((count % cfg.update_every) == 0,
                               do_refresh, lambda s: s, raw)

        pb = jax.vmap(
            lambda s, G: precond.precondition(s, G, count=count))(raw, gb)
        direction = blocking.from_blocks(pb, info)

        if cfg.graft != "none":
            graft_dir, new_acc = api.graft_direction(
                g32, leaf.graft, graft=cfg.graft, beta2=cfg.beta2,
                graft_eps=cfg.graft_eps)
            pnorm = jnp.linalg.norm(direction)
            gnorm = jnp.linalg.norm(graft_dir)
            direction = direction * (gnorm / (pnorm + 1e-16))
        else:
            graft_dir = g32
            new_acc = None

        if cfg.start_preconditioning_step > 0:
            use_precond = count >= cfg.start_preconditioning_step
            direction = jnp.where(use_precond, direction, graft_dir)
        return (direction.astype(g.dtype),
                PerLeafLeaf(stats=raw, graft=new_acc))

    def update_fn(updates, state, params=None):
        del params
        flat, treedef = jax.tree.flatten(updates)
        out, new_leaves = [], []
        for g, leaf in zip(flat, state.leaves):
            d, nl = update_leaf(g, leaf, state.count)
            out.append(d)
            new_leaves.append(nl)
        return (jax.tree.unflatten(treedef, out),
                PerLeafState(count=state.count + 1,
                             leaves=tuple(new_leaves)))

    return GradientTransformation(init_fn, update_fn)
