"""Async (one-step-stale) refresh pipeline: the property that makes
``refresh_mode="async"`` safe is STEP-SHIFTED EQUALITY — after every step t
the async engine's *committed view* (pending slot selected over the live
pool, ``api.committed_pools``) is bitwise identical to the inline engine's
pool state at t, for every refresh schedule, storage dtype and stats
reduction.  Only the update direction is one refresh stale (it is computed
before the step's refresh lands); the statistics stream itself never
diverges.  Plus: the pending double buffer is invisible to memory
accounting and checkpoints."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api
from repro.core.shampoo import ShampooConfig, shampoo
from repro.core.sketchy import SketchyConfig, sketchy

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PARAMS = {"a": np.float32, "b": np.float32, "c": np.float32}


def _params():
    return {"a": jnp.ones((48, 20), jnp.float32) * 0.1,
            "b": jnp.ones((10,), jnp.float32) * 0.1,
            "c": jnp.ones((70, 30), jnp.float32) * 0.1}


def _grads(t, params):
    k = jax.random.PRNGKey(100 + t)
    return {n: jax.random.normal(jax.random.fold_in(k, i), p.shape,
                                 jnp.float32) * 0.5
            for i, (n, p) in enumerate(sorted(params.items()))}


def _leaves_equal(a, b, msg):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y)), msg


def _sketchy_pair(schedule, dtype, **kw):
    mk = lambda mode: sketchy(SketchyConfig(
        rank=6, block_size=16, beta2=0.95, update_every=3,
        refresh_schedule=schedule, refresh_mode=mode,
        second_moment_dtype=dtype, **kw))
    return mk("inline"), mk("async")


@pytest.mark.parametrize("schedule", ["synchronized", "staggered"])
@pytest.mark.parametrize("dtype", ["fp32", "bf16", "int8"])
def test_async_committed_equals_inline(schedule, dtype):
    """Core pipeline property, over several refresh windows: at every step
    the async committed stats == inline stats BITWISE, and the per-leaf
    residue (diag fallback, grafting) is identical unshifted."""
    params = _params()
    tx_i, tx_a = _sketchy_pair(schedule, dtype)
    s_i, s_a = tx_i.init(params), tx_a.init(params)
    step_i = jax.jit(lambda g, s: tx_i.update(g, s, params))
    step_a = jax.jit(lambda g, s: tx_a.update(g, s, params))
    for t in range(8):
        g = _grads(t, params)
        _, s_i = step_i(g, s_i)
        _, s_a = step_a(g, s_a)
        _leaves_equal(api.committed_pools(s_a), s_i.pools,
                      f"committed != inline at step {t}")
        _leaves_equal(s_a.leaves, s_i.leaves,
                      f"leaf residue diverged at step {t}")
        assert all(bool(slot.valid.value)
                   for slot in s_a.pending.values()), t


def test_async_shampoo_parity():
    """Same property on the Shampoo engine (eigh root recompute pipelined
    instead of the FD shrink)."""
    params = _params()
    mk = lambda mode: shampoo(ShampooConfig(
        block_size=16, beta2=0.95, root_every=3, refresh_mode=mode))
    tx_i, tx_a = mk("inline"), mk("async")
    s_i, s_a = tx_i.init(params), tx_a.init(params)
    for t in range(7):
        g = _grads(t, params)
        _, s_i = tx_i.update(g, s_i, params)
        _, s_a = tx_a.update(g, s_a, params)
        _leaves_equal(api.committed_pools(s_a), s_i.pools,
                      f"shampoo committed != inline at step {t}")


def test_async_direction_is_one_refresh_stale():
    """The async direction at the first refresh step still uses the warm-up
    stats (the refresh hasn't committed), then picks it up next step —
    i.e. async actually pipelines instead of degenerating to inline."""
    params = _params()
    tx_i, tx_a = _sketchy_pair("synchronized", "fp32")
    s_i, s_a = tx_i.init(params), tx_a.init(params)
    diverged = False
    for t in range(6):
        g = _grads(t, params)
        d_i, s_i = tx_i.update(g, s_i, params)
        d_a, s_a = tx_a.update(g, s_a, params)
        same = all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(d_i),
                                   jax.tree.leaves(d_a)))
        if not same:
            diverged = True
    assert diverged, "async directions never lagged inline — no pipelining"


def test_profile_annotations_bitwise_noop():
    """Trace spans are observability only — bitwise identical states."""
    params = _params()
    for mode in ("inline", "async"):
        mk = lambda ann: sketchy(SketchyConfig(
            rank=6, block_size=16, update_every=2, refresh_mode=mode,
            profile_annotations=ann))
        tx0, tx1 = mk(False), mk(True)
        s0, s1 = tx0.init(params), tx1.init(params)
        for t in range(4):
            g = _grads(t, params)
            d0, s0 = tx0.update(g, s0, params)
            d1, s1 = tx1.update(g, s1, params)
            _leaves_equal((d0, s0), (d1, s1), f"annotations changed {mode}")


def test_pending_slot_excluded_from_memory_accounting():
    """The double buffer is transient: paper-metric second-moment bytes are
    identical across refresh modes (Fig. 1 numbers don't move)."""
    params = _params()
    for dtype in ("fp32", "int8"):
        tx_i, tx_a = _sketchy_pair("synchronized", dtype)
        b_i = api.second_moment_bytes(jax.eval_shape(tx_i.init, params))
        b_a = api.second_moment_bytes(jax.eval_shape(tx_a.init, params))
        assert b_i == b_a, (dtype, b_i, b_a)


def test_checkpoint_drops_pending_and_cross_restores(tmp_path):
    """Mid-flight checkpoints: the manifest of an async run is identical in
    leaf names to an inline run's (pending never saved); restores work in
    all four (save-mode x restore-mode) directions; a restored async state
    has valid=False (commit no-op) and keeps training."""
    import json

    from repro.train import checkpoint as ck

    params = _params()
    tx_i, tx_a = _sketchy_pair("synchronized", "int8")

    def run(tx, state, t0, t1):
        for t in range(t0, t1):
            _, state = tx.update(_grads(t, params), state, params)
        return state

    # save mid-flight: step 5 is past a refresh, pending is valid
    s_i = run(tx_i, tx_i.init(params), 0, 5)
    s_a = run(tx_a, tx_a.init(params), 0, 5)
    assert all(bool(sl.valid.value) for sl in s_a.pending.values())
    d_i, d_a = str(tmp_path / "inline"), str(tmp_path / "async")
    ck.save(d_i, 5, s_i)
    ck.save(d_a, 5, s_a)

    def names(d):
        with open(os.path.join(d, "step-5", "manifest.json")) as f:
            return [r["name"] for r in json.load(f)["leaves"]]

    assert names(d_i) == names(d_a)
    assert not any("pending" in n for n in names(d_a))

    tmpl_i = jax.eval_shape(tx_i.init, params)
    tmpl_a = jax.eval_shape(tx_a.init, params)
    for src in (d_i, d_a):
        r_i, _, _ = ck.restore(src, tmpl_i)
        assert r_i.pending is None
        _leaves_equal(r_i.pools, s_i.pools, f"{src} -> inline pools")
        r_a, _, _ = ck.restore(src, tmpl_a)
        for slot in r_a.pending.values():
            assert not bool(slot.valid.value)
            assert all(float(jnp.abs(jnp.asarray(v, jnp.float32)).max()) == 0
                       for v in jax.tree.leaves(api.untag(slot.stats)))
        # the zeroed pending commits as a no-op: live pools pass through
        _leaves_equal(api.committed_pools(r_a), r_a.pools, "commit not no-op")
        # resumed async run re-primes and keeps the shifted parity
        s_i2 = run(tx_i, r_i, 5, 9)
        s_a2 = run(tx_a, r_a, 5, 9)
        _leaves_equal(api.committed_pools(s_a2), s_i2.pools,
                      f"{src}: post-restore parity lost")


def test_async_parity_under_sharded_stats():
    """Step-shifted equality composes with stats_reduction="sharded": on a
    4-device data axis the async committed pools match the inline sharded
    engine bitwise at every step (fp32 wire: the merge itself is exact)."""
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    r = subprocess.run([sys.executable, "-c", r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.sharding.rules import shard_map
from repro.core import api, sketchy as sk
from repro.distributed import reduce as dreduce

rng = np.random.default_rng(0)
d = 16
params = {"w": jnp.asarray(rng.normal(size=(d, d)), jnp.float32),
          "v": jnp.asarray(rng.normal(size=(10,)), jnp.float32)}
mesh = jax.make_mesh((4,), ("data",))

def make_step(tx):
    def body(gl, s):
        gl = jax.tree.map(lambda x: x[0], gl)
        gm = dreduce.pmean(gl, "data")
        with dreduce.local_gradients(gl):
            return tx.update(gm, s, params)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data"), P()),
                             out_specs=(P(), P()), check_vma=False))

for sched in ("synchronized", "staggered"):
    mk = lambda mode: sk.sketchy(sk.SketchyConfig(
        rank=6, block_size=d, beta2=0.9, update_every=2, refresh_mode=mode,
        refresh_schedule=sched, stats_reduction="sharded",
        stats_wire_dtype="fp32"))
    tx_i, tx_a = mk("inline"), mk("async")
    step_i, step_a = make_step(tx_i), make_step(tx_a)
    s_i, s_a = tx_i.init(params), tx_a.init(params)
    for t in range(6):
        k = jax.random.PRNGKey(t)
        g = {n: jax.random.normal(jax.random.fold_in(k, i), (4,) + p.shape,
                                  jnp.float32)
             for i, (n, p) in enumerate(sorted(params.items()))}
        _, s_i = step_i(g, s_i)
        _, s_a = step_a(g, s_a)
        ci = jax.tree.leaves(api.committed_pools(s_a))
        li = jax.tree.leaves(s_i.pools)
        for a, b in zip(ci, li):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (sched, t)
print("SHARDED_ASYNC_PARITY_OK")
"""], capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    assert "SHARDED_ASYNC_PARITY_OK" in r.stdout
