"""Shape-aware kernel autotuner + fused quantized epilogues.

Covers the PR's acceptance criteria:
  * every candidate TileConfig is allclose to the XLA reference across
    ragged (N, d, k) pool shapes, including bf16 and int8 storage dtypes
    (tile sizes change the f32 accumulation order, never the math);
  * tune cache round-trip: tune -> serialize -> reload -> the registry
    interns an identical KernelSet (CI determinism);
  * the committed fixture validates against the candidate-space schema;
  * tune modes: "off" pins defaults, "auto" is hit-or-default (never
    measures), "force" measures and persists;
  * the fused int8 path never materializes the f32 eigenvector stack in
    the traced computation (jaxpr inspection — the dequantize lives inside
    the pallas kernel);
  * fused-engine parity: quantized_epilogue="on" agrees across backends
    and stays close to the boundary-dequantized int8 engine.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the deterministic sampling shim
    from hypothesis_compat import given, settings, strategies as st

from repro.core import api, quantize
from repro.core.fd import FDState, fd_update_batched
from repro.core.sketchy import SketchyConfig, sketchy
from repro.kernels import autotune, registry
from repro.kernels.gram import kernel as gram_kernel
from repro.kernels.gram import ref as gram_ref
from repro.kernels.lowrank import kernel as lowrank_kernel
from repro.kernels.lowrank import ref as lowrank_ref

RNG = np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _restore_tune_state():
    """Every test leaves the process-wide tune cache resolution as it found
    it (default fixture path, auto mode)."""
    yield
    autotune.reload(path=autotune.DEFAULT_CACHE_PATH, mode="auto")


def _mk(shape, dtype=jnp.float32):
    x = RNG.normal(size=shape)
    if jnp.dtype(dtype) == jnp.int8:
        return jnp.asarray(np.clip(np.round(x * 40), -127, 127), jnp.int8)
    return jnp.asarray(x, dtype)


# ------------------------------------------------------------ candidate space


def test_candidates_dedupe_and_default_first():
    cands = autotune.candidates("batched_gram", (4, 16, 8))
    assert cands[0] == autotune.effective("batched_gram", (4, 16, 8),
                                          autotune.DEFAULT_CONFIG)
    assert len(cands) == len(set(cands))
    # every candidate is already clamped to the shape (effective fixpoint)
    for c in cands:
        assert autotune.effective("batched_gram", (4, 16, 8), c) == c
        assert c.bn_stack <= 4 and c.bk <= 8 and c.bd <= 16


@settings(max_examples=6, deadline=None)
@given(N=st.integers(1, 9), d=st.integers(3, 40), k=st.integers(2, 24),
       dtype=st.sampled_from(["float32", "bfloat16", "int8"]))
def test_every_gram_candidate_matches_ref(N, d, k, dtype):
    """Property: ALL candidate tile configs compute the same Gram as the
    XLA reference on ragged pool shapes — tiles only change the f32
    accumulation order."""
    dt = jnp.dtype(dtype)
    a = _mk((N, d, k), dt)
    want = np.asarray(gram_ref.batched_gram_ref(a))
    tol = 2e-2 if dt == jnp.bfloat16 else 1e-5
    for cand in autotune.candidates("batched_gram", (N, d, k)):
        got = gram_kernel.batched_gram_pallas(
            a, bk=cand.bk, bd=cand.bd, bn_stack=cand.bn_stack, interpret=True)
        np.testing.assert_allclose(np.asarray(got), want, rtol=tol, atol=tol,
                                   err_msg=f"candidate {tuple(cand)}")


@pytest.mark.parametrize("N,d,k,r", [(1, 16, 8, 1), (5, 33, 8, 4),
                                     (8, 64, 12, 3)])
def test_every_mixed_gram_candidate_matches_ref(N, d, k, r):
    vq = _mk((N, d, k), jnp.int8)
    colw = jnp.abs(_mk((N, k))) + 0.1
    a = _mk((N, d, r))
    want = np.asarray(gram_ref.batched_gram_mixed_ref(vq, colw, a))
    for cand in autotune.candidates("batched_gram_mixed", (N, d, k, r)):
        got = gram_kernel.batched_gram_mixed_pallas(
            vq, colw, a, bd=cand.bd, bn_stack=cand.bn_stack, interpret=True)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                                   atol=1e-5, err_msg=f"{tuple(cand)}")


@pytest.mark.parametrize("N,d,ell,n", [(1, 16, 8, 16), (5, 33, 8, 20),
                                       (7, 32, 4, 64)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_every_apply_candidate_matches_ref(N, d, ell, n, dtype):
    dt = jnp.dtype(dtype)
    u = _mk((N, d, ell), dt)
    coeffs = _mk((N, ell))
    base = jnp.abs(_mk((N,)))
    g = _mk((N, d, n))
    want = np.asarray(lowrank_ref.batched_lowrank_apply_ref(
        u.astype(jnp.float32) if dt == jnp.int8 else u, coeffs, base, g))
    tol = 0.05 if dt == jnp.bfloat16 else 1e-4
    for cand in autotune.candidates("batched_lowrank_apply", (N, d, ell, n)):
        got = lowrank_kernel.batched_lowrank_apply_pallas(
            u, coeffs, base, g, bn=cand.bn, bn_stack=cand.bn_stack,
            interpret=True)
        np.testing.assert_allclose(np.asarray(got), want, rtol=tol, atol=tol,
                                   err_msg=f"{tuple(cand)}")


@pytest.mark.parametrize("N,d,k,r", [(1, 16, 8, 1), (5, 33, 8, 4)])
def test_every_project_quantize_candidate_matches_ref(N, d, k, r):
    e = k
    vq = _mk((N, d, k), jnp.int8)
    wt = _mk((N, k, e)) * 0.01
    a = _mk((N, d, r))
    wb = _mk((N, r, e))
    vals_w, scale_w = lowrank_ref.batched_project_quantize_ref(vq, wt, a, wb)
    shape = (N, d, k, r, e)
    for cand in autotune.candidates("batched_project_quantize", shape):
        vals, scale = lowrank_kernel.batched_project_quantize_pallas(
            vq, wt, a, wb, bn_stack=cand.bn_stack, interpret=True)
        # int8 outputs must match the reference quantizer bit for bit
        np.testing.assert_array_equal(np.asarray(vals), np.asarray(vals_w),
                                      err_msg=f"{tuple(cand)}")
        np.testing.assert_allclose(np.asarray(scale), np.asarray(scale_w),
                                   rtol=1e-6, err_msg=f"{tuple(cand)}")


# ------------------------------------------------------------------ tune modes


def _write_cache(path, entries):
    data = {"version": autotune.CACHE_VERSION,
            "entries": {k: dict(v._asdict()) for k, v in entries.items()}}
    with open(path, "w") as f:
        json.dump(data, f)


def test_mode_off_pins_defaults(tmp_path):
    cache = str(tmp_path / "cache.json")
    key = autotune.key_for("batched_gram", (4, 16, 8), jnp.float32)
    _write_cache(cache, {key: autotune.TileConfig(bn_stack=4, bk=8, bd=16)})
    autotune.reload(path=cache, mode="off")
    cfg = autotune.get_config("batched_gram", (4, 16, 8), jnp.float32)
    assert cfg == autotune.effective("batched_gram", (4, 16, 8),
                                     autotune.DEFAULT_CONFIG)
    assert cfg.bn_stack == 1


def test_mode_auto_hit_and_miss(tmp_path):
    cache = str(tmp_path / "cache.json")
    key = autotune.key_for("batched_gram", (4, 16, 8), jnp.float32)
    _write_cache(cache, {key: autotune.TileConfig(bn_stack=4, bk=8, bd=16)})
    autotune.reload(path=cache, mode="auto")
    hit = autotune.get_config("batched_gram", (4, 16, 8), jnp.float32)
    assert hit.bn_stack == 4 and hit.bk == 8 and hit.bd == 16
    # miss: default, and NO measurement side effect (file unchanged)
    before = os.path.getmtime(cache)
    miss = autotune.get_config("batched_gram", (9, 24, 6), jnp.float32)
    assert miss == autotune.effective("batched_gram", (9, 24, 6),
                                      autotune.DEFAULT_CONFIG)
    assert os.path.getmtime(cache) == before


def test_mode_force_tunes_and_persists(tmp_path):
    cache = str(tmp_path / "cache.json")
    autotune.reload(path=cache, mode="force")
    cfg = autotune.get_config("batched_gram", (3, 12, 6), jnp.float32)
    assert cfg in autotune.candidates("batched_gram", (3, 12, 6))
    with open(cache) as f:
        data = json.load(f)
    assert autotune.validate_cache(data) == []
    key = autotune.key_for("batched_gram", (3, 12, 6), jnp.float32)
    assert key in data["entries"]
    # second lookup is a plain cache hit (no re-measure): same answer
    assert autotune.get_config("batched_gram", (3, 12, 6),
                               jnp.float32) == cfg


# ------------------------------------------------- cache round-trip / interning


def test_cache_roundtrip_reloads_identical_kernelset(tmp_path):
    """tune -> serialize -> reload -> the registry interns an IDENTICAL
    KernelSet (the determinism contract CI relies on)."""
    cache = str(tmp_path / "cache.json")
    autotune.reload(path=cache, mode="force")
    tuned = autotune.get_config("batched_gram", (3, 12, 6), jnp.float32)

    autotune.reload(path=cache, mode="auto")
    snap1 = autotune.snapshot()
    ks1 = registry.get_kernels("pallas")
    assert ks1.tuned == snap1

    autotune.reload(path=cache, mode="auto")   # re-read the same file
    ks2 = registry.get_kernels("pallas")
    assert ks2 is ks1                          # interned on equal snapshot
    assert autotune.get_config("batched_gram", (3, 12, 6),
                               jnp.float32) == tuned

    # a different cache state yields a DIFFERENT set (no stale configs)
    autotune.reload(path=str(tmp_path / "other.json"), mode="auto")
    assert registry.get_kernels("pallas") is not ks1


def test_kernel_sets_still_interned_per_backend():
    ks_x = registry.get_kernels("xla")
    ks_p = registry.get_kernels("pallas")
    assert ks_x is registry.get_kernels("xla")
    assert ks_p is registry.get_kernels("pallas")
    assert ks_x.tuned == ks_p.tuned
    for name in ("batched_gram_mixed", "batched_lowrank_apply_quantized",
                 "batched_project_quantize"):
        assert callable(getattr(ks_x, name)) and callable(getattr(ks_p, name))


def test_committed_fixture_validates():
    """The committed tune cache must stay inside the candidate-space schema
    (also enforced by `python -m repro.kernels.autotune validate` in CI)."""
    assert os.path.exists(autotune.DEFAULT_CACHE_PATH), \
        "committed tune_cache.json fixture is missing"
    with open(autotune.DEFAULT_CACHE_PATH) as f:
        data = json.load(f)
    assert autotune.validate_cache(data) == []


def test_validate_cache_rejects_out_of_space_configs():
    key = autotune.key_for("batched_gram", (4, 16, 8), jnp.float32)
    bad = {"version": autotune.CACHE_VERSION,
           "entries": {key: {"bn_stack": 3, "bk": 999, "bd": 256, "bn": 256}}}
    assert autotune.validate_cache(bad)
    bad2 = {"version": autotune.CACHE_VERSION,
            "entries": {"cpu|nope|1x2x3|float32":
                        {"bn_stack": 1, "bk": 128, "bd": 256, "bn": 256}}}
    assert any("unknown kernel" in p for p in autotune.validate_cache(bad2))
    assert autotune.validate_cache([]) \
        and autotune.validate_cache({"version": 99, "entries": {}})


# ------------------------------------------------------- fused no-f32 contract


def _jaxprs_in(param):
    if hasattr(param, "jaxpr"):          # ClosedJaxpr
        return [param.jaxpr]
    if hasattr(param, "eqns"):           # raw Jaxpr
        return [param]
    if isinstance(param, (list, tuple)):
        return [j for p in param for j in _jaxprs_in(p)]
    return []


def _walk_avals(jaxpr, out):
    """Every intermediate aval in the traced computation, EXCLUDING pallas
    kernel bodies (in-kernel registers/VMEM are the point of fusion, not an
    HBM materialization)."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        for param in eqn.params.values():
            for sub in _jaxprs_in(param):
                _walk_avals(sub, out)
        for v in eqn.outvars:
            out.append(v.aval)


def _collect_avals(fn, *args):
    closed = jax.make_jaxpr(fn)(*args)
    out = []
    _walk_avals(closed.jaxpr, out)
    return out


def test_fused_refresh_never_materializes_f32_stack():
    """Acceptance criterion: with QuantizedPool state + pallas kernels, the
    traced FD refresh contains NO f32 tensor of the eigenvector-stack shape
    (N, d, ell) — dequantize happens in-registers inside the kernels.  The
    boundary-dequant path (positive control) does materialize it."""
    N, d, ell = 4, 32, 8
    ks = registry.get_kernels("pallas")
    qp = quantize.QuantizedPool(values=_mk((N, d, ell), jnp.int8),
                                scale=jnp.abs(_mk((N, 1, 1))) * 0.01 + 1e-3)
    s = jnp.abs(_mk((N, ell)))
    rho = jnp.abs(_mk((N,)))
    G = _mk((N, d, 1))

    def fused(vals, scale, s, rho, G):
        st = FDState(eigvecs=quantize.QuantizedPool(vals, scale),
                     eigvals=s, rho=rho)
        out = fd_update_batched(st, G, 0.99, kernels=ks)
        return out.eigvecs.values, out.eigvecs.scale, out.eigvals, out.rho

    banned = [a for a in _collect_avals(fused, qp.values, qp.scale, s, rho, G)
              if getattr(a, "shape", None) == (N, d, ell)
              and getattr(a, "dtype", None) == jnp.float32]
    assert banned == [], f"fused path materialized f32 stacks: {banned}"

    def boundary(vals, scale, s, rho, G):
        u = quantize.dequantize_stack(vals, scale)
        out = fd_update_batched(FDState(u, s, rho), G, 0.99, kernels=ks)
        return out.eigvecs

    control = [a for a in _collect_avals(boundary, qp.values, qp.scale, s,
                                         rho, G)
               if getattr(a, "shape", None) == (N, d, ell)
               and getattr(a, "dtype", None) == jnp.float32]
    assert control, "positive control: boundary dequant should materialize"


def test_fused_apply_never_materializes_f32_stack():
    N, d, ell, n = 4, 32, 8, 16
    ks = registry.get_kernels("pallas")
    vals, scale = _mk((N, d, ell), jnp.int8), jnp.abs(_mk((N, 1, 1))) * 0.01
    coeffs, base, g = _mk((N, ell)), jnp.abs(_mk((N,))), _mk((N, d, n))
    avals = _collect_avals(
        lambda v, sc, c, b, gg: ks.batched_lowrank_apply_quantized(
            v, sc, c, b, gg), vals, scale, coeffs, base, g)
    banned = [a for a in avals if getattr(a, "shape", None) == (N, d, ell)
              and getattr(a, "dtype", None) == jnp.float32]
    assert banned == [], f"quantized apply materialized f32: {banned}"


# ------------------------------------------------------------- fused FD / engine


def test_fused_fd_update_matches_jnp_fallback():
    """kernels=None and kernels=pallas produce byte-identical int8 output
    for the quantized FD update (same Gram math, same rounding rule)."""
    N, d, ell, r = 3, 24, 6, 2
    qp = quantize.quantize_stack(_mk((N, d, ell)) * 0.1)
    s = jnp.abs(_mk((N, ell)))
    s = jnp.sort(s, axis=-1)[..., ::-1].at[..., -1].set(0.0)
    rho = jnp.abs(_mk((N,))) * 0.1
    G = _mk((N, d, r))
    st = FDState(eigvecs=quantize.QuantizedPool(qp.values, qp.scale),
                 eigvals=s, rho=rho)
    out_jnp = fd_update_batched(st, G, 0.99, kernels=None)
    out_pal = fd_update_batched(st, G, 0.99,
                                kernels=registry.get_kernels("pallas"))
    out_xla = fd_update_batched(st, G, 0.99,
                                kernels=registry.get_kernels("xla"))
    for a, b in ((out_jnp, out_pal), (out_jnp, out_xla)):
        np.testing.assert_allclose(np.asarray(a.eigvals),
                                   np.asarray(b.eigvals), rtol=2e-3,
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(a.rho), np.asarray(b.rho),
                                   rtol=1e-4, atol=1e-6)
        assert isinstance(b.eigvecs, quantize.QuantizedPool)
        assert api.untag(b.eigvecs.values).dtype == jnp.int8


def _toy_params():
    return {"w": jnp.asarray(RNG.normal(size=(48, 20)), jnp.float32),
            "v": jnp.asarray(RNG.normal(size=(10,)), jnp.float32)}


def _toy_grad(t, params):
    r = np.random.default_rng(100 + t)
    return {k: jnp.asarray(r.normal(size=v.shape), jnp.float32)
            for k, v in params.items()}


def _run_engine(params, *, backend, epilogue, dtype="int8", steps=5,
                refresh_mode="inline"):
    tx = sketchy(SketchyConfig(rank=8, block_size=32, update_every=2,
                               kernel_backend=backend,
                               second_moment_dtype=dtype,
                               quantized_epilogue=epilogue,
                               refresh_mode=refresh_mode))
    s = tx.init(params)
    outs = []
    for t in range(steps):
        u, s = tx.update(_toy_grad(t, params), s, params)
        outs.append(u)
    return outs, s


def test_engine_fused_backends_agree():
    params = _toy_params()
    u_x, s_x = _run_engine(params, backend="xla", epilogue="on")
    u_p, s_p = _run_engine(params, backend="pallas", epilogue="on")
    for t in range(len(u_x)):
        for a, b in zip(jax.tree.leaves(u_x[t]), jax.tree.leaves(u_p[t])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)
    # fused storage: eigvec stacks stay QuantizedPool in engine state
    key = next(iter(s_p.pools))
    st = s_p.pools[key]
    for side in (st.left, st.right):
        assert isinstance(side.eigvecs, quantize.QuantizedPool)
        assert api.untag(side.eigvecs.values).dtype == jnp.int8


def test_engine_fused_tracks_boundary_dequant_direction():
    """Fused int8 changes the rounding scheme, not the math: the update
    direction stays cosine-aligned with the boundary-dequantized engine."""
    params = _toy_params()
    u_off, _ = _run_engine(params, backend="xla", epilogue="off")
    u_on, _ = _run_engine(params, backend="xla", epilogue="on")
    for t in range(len(u_off)):
        for a, b in zip(jax.tree.leaves(u_off[t]), jax.tree.leaves(u_on[t])):
            a, b = np.asarray(a).ravel(), np.asarray(b).ravel()
            cos = np.dot(a, b) / (np.linalg.norm(a) * np.linalg.norm(b)
                                  + 1e-30)
            assert cos > 0.999, (t, cos)


def test_engine_auto_is_off_on_xla_backend():
    """quantized_epilogue="auto" only engages on the pallas backend: the
    xla/CPU default keeps the PR-4 boundary-dequant numerics bitwise."""
    params = _toy_params()
    u_auto, _ = _run_engine(params, backend="xla", epilogue="auto")
    u_off, _ = _run_engine(params, backend="xla", epilogue="off")
    for t in range(len(u_auto)):
        for a, b in zip(jax.tree.leaves(u_auto[t]), jax.tree.leaves(u_off[t])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_fused_async_refresh_parity():
    """Fused int8 composes with the async one-step-stale refresh pipeline:
    the committed pools after step t equal the inline pools at t bitwise
    (the step-shifted parity contract), with the QuantizedPool pending
    slots selecting/committing on raw int8 leaves."""
    params = _toy_params()
    _, s_in = _run_engine(params, backend="pallas", epilogue="on", steps=4)
    u_as, s_as = _run_engine(params, backend="pallas", epilogue="on",
                             steps=4, refresh_mode="async")
    committed = api.committed_pools(s_as)
    for key in s_in.pools:
        for a, b in zip(jax.tree.leaves(api.untag(s_in.pools[key])),
                        jax.tree.leaves(api.untag(committed[key]))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for u in u_as:
        for leaf in jax.tree.leaves(u):
            assert np.all(np.isfinite(np.asarray(leaf)))


def test_engine_config_validates_epilogue():
    with pytest.raises(ValueError, match="quantized_epilogue"):
        api.EngineConfig(quantized_epilogue="maybe")


def test_requantize_pool_passes_quantized_through():
    """A QuantizedPool produced in-kernel is stored as-is (re-tagged), never
    double-rounded."""
    x = _mk((3, 8, 4)) * 0.1
    tagged = quantize.quantize_pool(
        api.tag(x, "second_moment", blocked=True), "int8")
    fresh = quantize.quantize_stack(_mk((3, 8, 4)) * 0.2)
    out = quantize.requantize_pool(tagged, fresh,
                                   key=jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(api.untag(out.values)),
                                  np.asarray(fresh.values))
    np.testing.assert_array_equal(np.asarray(api.untag(out.scale)),
                                  np.asarray(fresh.scale))
    assert out.values.meta.role == "second_moment"


def test_compute_view_keeps_containers():
    x = _mk((3, 8, 4))
    tagged = quantize.quantize_pool(
        api.tag(x, "second_moment", blocked=True), "int8")
    view = quantize.compute_view(tagged)
    assert isinstance(view, quantize.QuantizedPool)
    assert not isinstance(view.values, api.Tagged)
    # and dequantizing the view matches the boundary dequant exactly
    np.testing.assert_array_equal(
        np.asarray(quantize.dequantize_stack(view.values, view.scale)),
        np.asarray(quantize.dequantize_pool(tagged)))
