"""Blocking roundtrip properties."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the deterministic sampling shim
    from hypothesis_compat import given, settings, strategies as st

from repro.core import blocking


@settings(max_examples=25, deadline=None)
@given(
    lead=st.lists(st.integers(1, 3), min_size=0, max_size=2),
    m=st.integers(1, 70), n=st.integers(1, 70),
    bs=st.sampled_from([8, 16, 32]),
)
def test_roundtrip(lead, m, n, bs):
    shape = tuple(lead) + (m, n)
    info = blocking.analyze(shape, bs)
    x = jnp.asarray(np.random.default_rng(0).normal(size=shape), jnp.float32)
    if info.kind == "diag":
        assert min(m, n) == 1 or len(shape) < 2
        return
    blocks = blocking.to_blocks(x, info)
    assert blocks.shape == (info.num_blocks, info.bs_m, info.bs_n)
    back = blocking.from_blocks(blocks, info)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_block_sizes_bounded():
    info = blocking.analyze((5000, 3000), 1024)
    assert info.bs_m <= 1024 and info.bs_n <= 1024
    assert info.mb * info.bs_m >= 5000
    assert info.nb * info.bs_n >= 3000


def test_vectors_are_diag():
    assert blocking.analyze((128,), 64).kind == "diag"
    assert blocking.analyze((), 64).kind == "diag"
    assert blocking.analyze((7, 1), 64).kind == "diag"
