"""Checkpointing: atomic roundtrip, async overlap, GC, restart cursor."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ckpt


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                       "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)},
            "opt": (jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                    jnp.asarray(3, jnp.int32))}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    state = _state()
    ckpt.save(d, 7, state, extra={"data_step": 7})
    restored, step, extra = ckpt.restore(d, _state(seed=1))
    assert step == 7 and extra["data_step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _state())
    assert not [x for x in os.listdir(d) if x.startswith("tmp-")]


def test_gc_keeps_last_three(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        ckpt.save(d, s, _state())
    assert ckpt.all_steps(d) == [3, 4, 5]


def test_latest_and_specific_step(tmp_path):
    d = str(tmp_path)
    s0, s1 = _state(0), _state(1)
    ckpt.save(d, 1, s0)
    ckpt.save(d, 2, s1)
    r, step, _ = ckpt.restore(d, _state(2))
    assert step == 2
    r1, step1, _ = ckpt.restore(d, _state(2), step=1)
    assert step1 == 1
    np.testing.assert_array_equal(np.asarray(r1["params"]["w"]),
                                  np.asarray(s0["params"]["w"]))


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ac = ckpt.AsyncCheckpointer(d)
    state = _state()
    for s in (10, 20):
        ac.save(s, state)
    ac.wait()
    assert ckpt.latest_step(d) == 20


def test_structure_mismatch_rejected(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 0, _state())
    bad = {"params": {"w": jnp.zeros((8, 4))}}
    try:
        ckpt.restore(d, bad)
        assert False, "should have raised"
    except ValueError:
        pass
