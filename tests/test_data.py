"""Data pipeline determinism + host sharding."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM


def test_deterministic_across_calls():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    a = SyntheticLM(cfg).batch(5)
    b = SyntheticLM(cfg).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_steps_differ():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
    p = SyntheticLM(cfg)
    assert not np.array_equal(p.batch(0)["tokens"], p.batch(1)["tokens"])


def test_host_sharding_disjoint_and_sized():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
    p = SyntheticLM(cfg)
    h0 = p.batch(0, host=0, num_hosts=4)
    h1 = p.batch(0, host=1, num_hosts=4)
    assert h0["tokens"].shape == (2, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_labels_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_codebooks_and_embeds():
    b = SyntheticLM(DataConfig(vocab_size=50, seq_len=8, global_batch=2,
                               num_codebooks=4)).batch(0)
    assert b["tokens"].shape == (2, 8, 4)
    b = SyntheticLM(DataConfig(vocab_size=50, seq_len=8, global_batch=2,
                               embed_dim=16)).batch(0)
    assert b["embeds"].shape == (2, 8, 16)
