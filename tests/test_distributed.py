"""Distributed substrates that need >1 device: run in subprocesses with
--xla_force_host_platform_device_count (the main pytest process must keep
seeing ONE device, per the dry-run contract)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run_py(code: str, devices: int = 8) -> str:
    env = {**ENV,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


def test_pipeline_parallel_matches_sequential():
    out = _run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline_parallel import gpipe_apply
mesh = jax.make_mesh((4,), ("pipe",))
rng = np.random.default_rng(0)
L, D = 8, 16
layers = {"w": jnp.asarray(rng.normal(size=(L, D, D)) * 0.2, jnp.float32),
          "b": jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32)}
def block(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])
x = jnp.asarray(rng.normal(size=(8, 4, D)), jnp.float32)
ref = x
for i in range(L):
    ref = block(jax.tree.map(lambda a: a[i], layers), ref)
got = gpipe_apply(mesh, "pipe", layers, block, x, microbatches=4)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
# gradients flow through the pipeline
def loss(ls):
    return jnp.sum(gpipe_apply(mesh, "pipe", ls, block, x, 4) ** 2)
g = jax.grad(loss)(layers)
assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
print("PIPE_OK")
""")
    assert "PIPE_OK" in out


def test_compressed_gradient_allreduce():
    out = _run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.train.compression import compressed_mean_grads
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
grads = {"a": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(128,)), jnp.float32)}
# replicated input => mean == input; compression error must be small
got = compressed_mean_grads(grads, mesh, ("data",))
for k in grads:
    ref = np.asarray(grads[k])
    err = np.abs(np.asarray(got[k]) - ref).max() / (np.abs(ref).max() + 1e-9)
    assert err < 0.02, (k, err)
print("COMPRESS_OK")
""")
    assert "COMPRESS_OK" in out


def test_elastic_plan_and_remesh():
    out = _run_py(r"""
import jax
from repro.train.elastic import plan_mesh, remesh
plan = plan_mesh(8, model_parallel=2, target_global_batch=64)
assert plan.mesh_shape == (4, 2)
mesh = remesh(plan)
assert mesh.devices.shape == (4, 2)
# lose two devices -> dp shrinks, batch stays divisible
plan = plan_mesh(6, model_parallel=2, target_global_batch=64)
assert plan.mesh_shape == (3, 2)
assert plan.global_batch % 3 == 0
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


@pytest.mark.parametrize("arch,shape", [
    ("paper-lm-100m", "train_4k"),
    ("zamba2-7b", "decode_32k"),
    ("deepseek-moe-16b", "train_4k"),
    ("mamba2-370m", "long_500k"),
])
def test_dryrun_smoke_cells(arch, shape, tmp_path):
    """End-to-end dry-run machinery on a tiny mesh + reduced configs."""
    out = str(tmp_path / "r.json")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--devices", "8", "--mesh", "2x4:data,model",
         "--smoke", "--out", out],
        capture_output=True, text=True, env=ENV, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    rep = json.load(open(out))
    assert rep["full"]["compile_s"] > 0
    assert rep["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rep["cost"]["flops_per_device"] > 0


def test_dryrun_multipod_smoke(tmp_path):
    out = str(tmp_path / "r.json")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "paper-lm-100m", "--shape", "train_4k", "--devices", "16",
         "--mesh", "2x2x4:pod,data,model", "--smoke", "--skip-probes",
         "--out", out],
        capture_output=True, text=True, env=ENV, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    rep = json.load(open(out))
    assert rep["axes"] == ["pod", "data", "model"]


def test_straggler_monitor():
    from repro.train.elastic import StragglerMonitor
    m = StragglerMonitor(window=20, k=3.0)
    # 15 uniform ~10ms steps, slight jitter
    m.times.extend([0.010 + 1e-4 * (i % 3) for i in range(15)])
    m._t0 = __import__("time").perf_counter() - 0.5  # fake a 500ms step
    m.stop()
    assert m.flagged == 1
    m._t0 = __import__("time").perf_counter() - 0.0101  # normal step
    m.stop()
    assert m.flagged == 1
