"""Distributed substrates that need >1 device: run in subprocesses with
--xla_force_host_platform_device_count (the main pytest process must keep
seeing ONE device, per the dry-run contract)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run_py(code: str, devices: int = 8) -> str:
    env = {**ENV,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


def test_pipeline_parallel_matches_sequential():
    out = _run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline_parallel import gpipe_apply
mesh = jax.make_mesh((4,), ("pipe",))
rng = np.random.default_rng(0)
L, D = 8, 16
layers = {"w": jnp.asarray(rng.normal(size=(L, D, D)) * 0.2, jnp.float32),
          "b": jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32)}
def block(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])
x = jnp.asarray(rng.normal(size=(8, 4, D)), jnp.float32)
ref = x
for i in range(L):
    ref = block(jax.tree.map(lambda a: a[i], layers), ref)
got = gpipe_apply(mesh, "pipe", layers, block, x, microbatches=4)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
# gradients flow through the pipeline
def loss(ls):
    return jnp.sum(gpipe_apply(mesh, "pipe", ls, block, x, 4) ** 2)
g = jax.grad(loss)(layers)
assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
print("PIPE_OK")
""")
    assert "PIPE_OK" in out


def test_compressed_gradient_allreduce():
    out = _run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.train.compression import compressed_mean_grads
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
grads = {"a": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(128,)), jnp.float32)}
# replicated input => mean == input; compression error must be small
got = compressed_mean_grads(grads, mesh, ("data",))
for k in grads:
    ref = np.asarray(grads[k])
    err = np.abs(np.asarray(got[k]) - ref).max() / (np.abs(ref).max() + 1e-9)
    assert err < 0.02, (k, err)
print("COMPRESS_OK")
""")
    assert "COMPRESS_OK" in out


def test_elastic_plan_and_remesh():
    out = _run_py(r"""
import jax
from repro.train.elastic import plan_mesh, remesh
plan = plan_mesh(8, model_parallel=2, target_global_batch=64)
assert plan.mesh_shape == (4, 2)
mesh = remesh(plan)
assert mesh.devices.shape == (4, 2)
# lose two devices -> dp shrinks, batch stays divisible
plan = plan_mesh(6, model_parallel=2, target_global_batch=64)
assert plan.mesh_shape == (3, 2)
assert plan.global_batch % 3 == 0
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


@pytest.mark.parametrize("arch,shape", [
    ("paper-lm-100m", "train_4k"),
    ("zamba2-7b", "decode_32k"),
    ("deepseek-moe-16b", "train_4k"),
    ("mamba2-370m", "long_500k"),
])
def test_dryrun_smoke_cells(arch, shape, tmp_path):
    """End-to-end dry-run machinery on a tiny mesh + reduced configs."""
    out = str(tmp_path / "r.json")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--devices", "8", "--mesh", "2x4:data,model",
         "--smoke", "--out", out],
        capture_output=True, text=True, env=ENV, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    rep = json.load(open(out))
    assert rep["full"]["compile_s"] > 0
    assert rep["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rep["cost"]["flops_per_device"] > 0


def test_dryrun_multipod_smoke(tmp_path):
    out = str(tmp_path / "r.json")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "paper-lm-100m", "--shape", "train_4k", "--devices", "16",
         "--mesh", "2x2x4:pod,data,model", "--smoke", "--skip-probes",
         "--out", out],
        capture_output=True, text=True, env=ENV, cwd=REPO, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    rep = json.load(open(out))
    assert rep["axes"] == ["pod", "data", "model"]


def test_butterfly_merge_under_shard_map():
    """Log-depth ppermute butterfly on a faked 4-device mesh: merged sketch
    == exact union covariance within the FD bound; int8 wire stays close to
    the exact fp32 wire."""
    out = _run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.sharding.rules import shard_map
from repro.core.fd import FDState, fd_update_batched, fd_covariance
from repro.distributed import reduce as dreduce

mesh = jax.make_mesh((4,), ("data",))
d, ell, N = 16, 6, 2
rng = np.random.default_rng(0)
G = jnp.asarray(rng.normal(size=(4, N, d, 1)), jnp.float32)

def run(wire):
    def body(Gl):
        st = FDState(jnp.zeros((N, d, ell)), jnp.zeros((N, ell)),
                     jnp.zeros((N,)))
        st = fd_update_batched(st, Gl[0])
        assert dreduce.bound_axis_size("data") == 4
        return dreduce.butterfly_merge_fd(st, axis="data", axis_size=4,
                                          wire_dtype=wire)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data"),),
                             out_specs=FDState(P(), P(), P()),
                             check_vma=False))(G)

out = run("fp32")
for n in range(N):
    exact = sum(np.outer(G[i, n, :, 0], G[i, n, :, 0]) for i in range(4))
    st_n = FDState(out.eigvecs[n], out.eigvals[n], out.rho[n])
    err = np.linalg.norm(exact - np.asarray(fd_covariance(st_n)), 2)
    assert err <= float(out.rho[n]) * (1 + 1e-4) + 1e-3, (n, err)
out8 = run("int8")
rel = np.abs(np.asarray(out8.eigvals) - np.asarray(out.eigvals)).max() / \
    (np.abs(np.asarray(out.eigvals)).max() + 1e-9)
assert rel < 0.1, rel
print("BUTTERFLY_OK")
""", devices=4)
    assert "BUTTERFLY_OK" in out


def test_sharded_stats_engine_parity_and_bound():
    """Engine acceptance criteria: "sharded" under an unbound axis and on a
    1-sized data axis is BITWISE equal to replicated; on a 4-sized axis the
    merged pool sketch matches the exact (1/P) sum_i G_i G_i^T stream within
    the FD merge error bound."""
    out = _run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.sharding.rules import shard_map
from repro.core import api, sketchy as sk
from repro.core.fd import FDState, fd_covariance
from repro.distributed import reduce as dreduce

rng = np.random.default_rng(0)
d = 16
params = {"w": jnp.asarray(rng.normal(size=(d, d)), jnp.float32),
          "v": jnp.asarray(rng.normal(size=(10,)), jnp.float32)}
mk_cfg = lambda **kw: sk.SketchyConfig(rank=6, block_size=d, beta2=0.9,
                                       update_every=1, **kw)
tx_r = sk.sketchy(mk_cfg())
tx_s = sk.sketchy(mk_cfg(stats_reduction="sharded", stats_wire_dtype="fp32"))
state0 = tx_r.init(params)
grads = {"w": jnp.asarray(rng.normal(size=(4, d, d)), jnp.float32),
         "v": jnp.asarray(rng.normal(size=(4, 10)), jnp.float32)}
gmean = jax.tree.map(lambda g: g.mean(0), grads)

def run(tx, g, s, steps=3):
    for _ in range(steps):
        dirs, s = tx.update(g, s, params)
    return dirs, s

# 1) unbound axis: bitwise == replicated
ref = jax.jit(lambda g, s: run(tx_r, g, s))(gmean, state0)
got = jax.jit(lambda g, s: run(tx_s, g, s))(gmean, state0)
for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
    assert np.array_equal(np.asarray(a), np.asarray(b))
print("UNBOUND_PARITY_OK")

def sharded_run(mesh, g, s):
    def body(gl, s):
        gl = jax.tree.map(lambda x: x[0], gl)
        gm = dreduce.pmean(gl, "data")
        with dreduce.local_gradients(gl):
            return run(tx_s, gm, s)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data"), P()),
                             out_specs=(P(), P()), check_vma=False))(g, s)

# 2) data-axis size 1: bitwise == replicated
d1 = sharded_run(jax.make_mesh((1,), ("data",)),
                 jax.tree.map(lambda g: g[None], gmean), state0)
for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(d1)):
    assert np.array_equal(np.asarray(a), np.asarray(b))
print("P1_PARITY_OK")

# 3) data-axis size 4: merged sketch obeys the FD bound against the exact
#    (1/P) sum_i G_i G_i^T stream (beta2-decayed across refreshes)
steps, beta2 = 3, 0.9
_, s4 = sharded_run(jax.make_mesh((4,), ("data",)), grads, state0)
S = np.zeros((d, d))
Gw = np.asarray(grads["w"])
for _ in range(steps):
    S = beta2 * S + sum(Gw[i] @ Gw[i].T for i in range(4)) / 4.0
stats = api.pool_stats(api.get_stage(s4, "precond")
                       if isinstance(s4, dict) else s4)
left = stats.left
sk_state = FDState(left.eigvecs[0], left.eigvals[0], left.rho[0])
err = np.linalg.norm(S - np.asarray(fd_covariance(sk_state)), 2)
rho = float(sk_state.rho)
assert err <= rho * (1 + 1e-3) + 1e-2, (err, rho)
print("P4_BOUND_OK", err, rho)
""", devices=4)
    assert "UNBOUND_PARITY_OK" in out
    assert "P1_PARITY_OK" in out
    assert "P4_BOUND_OK" in out


def test_sharded_trainer_end_to_end():
    """make_train_step(data_parallel_mesh=...) trains the reduced LM with
    stats_reduction="sharded" on a 4-device mesh; loss stays finite and
    tracks the replicated run."""
    out = _run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_reduced
from repro.core.factory import OptimizerConfig, make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as model_lib
from repro.train.trainer import make_train_step

cfg = get_reduced("paper_lm_100m")
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                              global_batch=8))
params = model_lib.init_params(cfg, jax.random.PRNGKey(0))

def losses(stats_reduction, mesh):
    tx = make_optimizer(OptimizerConfig(
        name="sketchy", learning_rate=1e-3, total_steps=8, rank=8,
        block_size=64, update_every=2, schedule="constant",
        stats_reduction=stats_reduction))
    p, s = params, tx.init(params)
    # donate=False: the module-level `params` feeds both losses() runs
    step = make_train_step(cfg, tx, data_parallel_mesh=mesh, donate=False)
    out = []
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        p, s, m = step(p, s, batch)
        out.append(float(m["loss"]))
    return out

mesh = jax.make_mesh((4,), ("data",))
l_shard = losses("sharded", mesh)
l_repl = losses("replicated", None)
assert all(np.isfinite(l_shard)), l_shard
# same batches, same mean grads => trajectories track closely
for a, b in zip(l_shard, l_repl):
    assert abs(a - b) < 0.15 * abs(b) + 0.05, (l_shard, l_repl)
print("TRAINER_SHARDED_OK", l_shard[-1], l_repl[-1])
""", devices=4)
    assert "TRAINER_SHARDED_OK" in out


def test_remesh_opt_state_rebalances_pools():
    """remesh_opt_state routes pooled stacks through the blocks sharding:
    the leading opt_blocks dim is actually distributed on the new mesh, and
    re-balances again when the mesh shrinks."""
    out = _run_py(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import api, sketchy as sk
from repro.train.elastic import plan_mesh, remesh, remesh_opt_state

rng = np.random.default_rng(0)
params = {f"w{i}": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
          for i in range(4)}   # 4 leaves x 2 blocks of (32, 32) => N=8
tx = sk.sketchy(sk.SketchyConfig(rank=4, block_size=32))
state = tx.init(params)

def pool_leaf_devices(state):
    pools = state.pools
    (key,) = pools
    leaf = pools[key].left.eigvecs
    arr = leaf.value if isinstance(leaf, api.Tagged) else leaf
    return arr.sharding, arr

mesh = remesh(plan_mesh(8, model_parallel=2, target_global_batch=64))
assert mesh.devices.shape == (4, 2)
_, state8 = remesh_opt_state(state, params, mesh)
sh, arr = pool_leaf_devices(state8)
assert sh.spec[0] is not None, sh  # leading blocks dim is sharded
assert len({d for d in arr.devices()}) == 8

# mesh shrinks 8 -> 4 devices: pools re-balance directly
mesh4 = remesh(plan_mesh(4, model_parallel=2, target_global_batch=64))
assert mesh4.devices.shape == (2, 2)
_, state4 = remesh_opt_state(state8, params, mesh4)
sh4, arr4 = pool_leaf_devices(state4)
assert sh4.spec[0] is not None, sh4
assert len({d for d in arr4.devices()}) == 4
np.testing.assert_array_equal(np.asarray(arr4), np.asarray(arr))
print("REMESH_POOLS_OK")
""")
    assert "REMESH_POOLS_OK" in out


def test_merge_sketches_on_shrink():
    """Departing shards' sketch stacks fold into the survivors' via the
    mergeable-sketch primitive (host-side, no mesh needed)."""
    import jax.numpy as jnp
    from repro.core import api
    from repro.core.fd import FDState, fd_covariance, fd_merge_batched
    from repro.train.elastic import merge_sketches_on_shrink

    rng = np.random.default_rng(0)
    d, ell, N = 12, 4, 2

    def mk_stack():
        U = np.linalg.qr(rng.normal(size=(d, ell)))[0]
        s = np.sort(rng.uniform(1, 2, size=ell))[::-1]
        s[-1] = 0.0
        return FDState(
            eigvecs=jnp.asarray(np.stack([U] * N), jnp.float32),
            eigvals=jnp.asarray(np.stack([s] * N), jnp.float32),
            rho=jnp.asarray(rng.uniform(0, 1, size=N), jnp.float32))

    a, b = mk_stack(), mk_stack()
    tag = lambda st: FDState(*(api.tag(x, "second_moment", blocked=True)
                               for x in st))
    merged = merge_sketches_on_shrink([{"pool": tag(a)}, {"pool": tag(b)}])
    direct = fd_merge_batched(a, b)
    got = merged["pool"]
    assert isinstance(got.eigvecs, api.Tagged)  # tags survive the fold
    got_u = FDState(*api.untag(list(got)))
    for n in range(N):
        np.testing.assert_allclose(
            np.asarray(fd_covariance(FDState(got_u.eigvecs[n],
                                             got_u.eigvals[n],
                                             got_u.rho[n]))),
            np.asarray(fd_covariance(FDState(direct.eigvecs[n],
                                             direct.eigvals[n],
                                             direct.rho[n]))), atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_u.rho),
                               np.asarray(direct.rho), rtol=1e-6)


def test_straggler_monitor():
    from repro.train.elastic import StragglerMonitor
    m = StragglerMonitor(window=20, k=3.0)
    # 15 uniform ~10ms steps, slight jitter
    m.times.extend([0.010 + 1e-4 * (i % 3) for i in range(15)])
    m._t0 = __import__("time").perf_counter() - 0.5  # fake a 500ms step
    m.stop()
    assert m.flagged == 1
    m._t0 = __import__("time").perf_counter() - 0.0101  # normal step
    m.stop()
    assert m.flagged == 1
