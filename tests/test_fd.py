"""Property tests for the Frequent Directions core (paper Alg. 1, Lemma 1,
Observation 6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the deterministic sampling shim
    from hypothesis_compat import given, settings, strategies as st

from repro.core.fd import (FDState, fd_apply_inverse_root, fd_covariance,
                           fd_init, fd_merge, fd_merge_batched, fd_update,
                           fd_weighted_factor)

jax.config.update("jax_enable_x64", False)


def _stream(seed, d, T, decay=3.0):
    rng = np.random.default_rng(seed)
    basis = np.linalg.qr(rng.normal(size=(d, d)))[0]
    scales = np.exp(-np.arange(d) / decay)
    return [basis @ (scales * rng.normal(size=d)) for _ in range(T)]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.integers(8, 48),
       ell=st.integers(2, 8), T=st.integers(5, 60))
def test_lemma1_escaped_mass_bound(seed, d, ell, T):
    """rho_{1:T} <= min_k sum_{i>k} lambda_i / (ell - k)  (Lemma 1)."""
    ell = min(ell, d)
    st_ = fd_init(d, ell)
    G = np.zeros((d, d))
    for g in _stream(seed, d, T):
        G += np.outer(g, g)
        st_ = fd_update(st_, jnp.asarray(g, jnp.float32))
    lam = np.maximum(np.linalg.eigvalsh(G)[::-1], 0)
    bound = min(lam[k:].sum() / (ell - k) for k in range(ell))
    assert float(st_.rho) <= bound * (1 + 1e-4) + 1e-5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_fd_operator_norm_error(seed):
    """||G - Gbar||_op <= rho_{1:T} (FD fundamental guarantee)."""
    d, ell, T = 32, 8, 100
    st_ = fd_init(d, ell)
    G = np.zeros((d, d))
    for g in _stream(seed, d, T):
        G += np.outer(g, g)
        st_ = fd_update(st_, jnp.asarray(g, jnp.float32))
    err = np.linalg.norm(G - np.asarray(fd_covariance(st_)), 2)
    assert err <= float(st_.rho) * (1 + 1e-4) + 1e-4


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), beta2=st.sampled_from([0.9, 0.99, 0.999]))
def test_ema_fd_obs6(seed, beta2):
    """|| Gbar^{(b2)} - G^{(b2)} ||_op <= rho^{(b2)}_{1:T}  (Obs. 6)."""
    d, ell, T = 24, 6, 80
    st_ = fd_init(d, ell)
    G = np.zeros((d, d))
    for g in _stream(seed, d, T):
        G = beta2 * G + np.outer(g, g)
        st_ = fd_update(st_, jnp.asarray(g, jnp.float32), beta2=beta2)
    err = np.linalg.norm(G - np.asarray(fd_covariance(st_)), 2)
    assert err <= float(st_.rho) * (1 + 1e-4) + 1e-4


def test_sketch_invariants():
    """Eigvecs orthonormal, eigvals descending with zero tail, rho monotone
    (beta2=1)."""
    d, ell = 40, 10
    st_ = fd_init(d, ell)
    prev_rho = 0.0
    rng = np.random.default_rng(0)
    for _ in range(50):
        g = rng.normal(size=d)
        st_ = fd_update(st_, jnp.asarray(g, jnp.float32))
        s = np.asarray(st_.eigvals)
        assert np.all(np.diff(s) <= 1e-4 * max(s.max(), 1.0))
        assert abs(s[-1]) < 1e-4 * max(s.max(), 1.0)
        # pseudo-orthonormal: U^T U == diag with entries in {0, 1}
        # (columns are zero until the stream fills the sketch rank)
        G = np.asarray(st_.eigvecs).T @ np.asarray(st_.eigvecs)
        diag = np.diag(G)
        assert np.all((np.abs(diag - 1) < 5e-3) | (np.abs(diag) < 5e-3))
        off = G - np.diag(diag)
        assert np.abs(off).max() < 5e-3
        assert float(st_.rho) >= prev_rho - 1e-6
        prev_rho = float(st_.rho)


def test_full_rank_exact():
    """ell >= stream rank => sketch is exact and rho == 0 (paper §3.3
    remark: low-rank G_T needs no sketching error)."""
    d, r, ell = 20, 4, 8
    rng = np.random.default_rng(1)
    W = np.linalg.qr(rng.normal(size=(d, r)))[0]
    st_ = fd_init(d, ell)
    G = np.zeros((d, d))
    for _ in range(30):
        g = W @ rng.normal(size=r)
        G += np.outer(g, g)
        st_ = fd_update(st_, jnp.asarray(g, jnp.float32))
    assert float(st_.rho) < 1e-4
    np.testing.assert_allclose(np.asarray(fd_covariance(st_)), G,
                               atol=1e-3 * np.linalg.norm(G, 2))


# ---------------------------------------------------------------- fd_merge
# (distributed sketching: src/repro/distributed/ merges per-shard sketches)


def _sketch(stream, d, ell):
    st_ = fd_init(d, ell)
    for g in stream:
        st_ = fd_update(st_, jnp.asarray(g, jnp.float32))
    return st_


def test_merge_commutative_up_to_sign():
    """a (+) b and b (+) a agree as operators (eigvecs may flip sign)."""
    d, ell = 24, 6
    a = _sketch(_stream(0, d, 30), d, ell)
    b = _sketch(_stream(1, d, 30), d, ell)
    ab, ba = fd_merge(a, b), fd_merge(b, a)
    np.testing.assert_allclose(np.asarray(ab.eigvals), np.asarray(ba.eigvals),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(ab.rho), float(ba.rho), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(fd_covariance(ab)),
                               np.asarray(fd_covariance(ba)),
                               atol=1e-3 * max(float(ab.eigvals[0]), 1.0))


def test_merge_associative_on_low_rank():
    """With total rank <= ell the merge is exact, so grouping is immaterial:
    (a+b)+c == a+(b+c) and rho stays 0 (no escaped mass to order)."""
    d, r, ell = 20, 2, 8
    rng = np.random.default_rng(3)
    sketches, G = [], np.zeros((d, d))
    for k in range(3):
        W = np.linalg.qr(rng.normal(size=(d, r)))[0]
        stream = [W @ rng.normal(size=r) for _ in range(15)]
        G += sum(np.outer(g, g) for g in stream)
        sketches.append(_sketch(stream, d, ell))
    a, b, c = sketches
    left = fd_merge(fd_merge(a, b), c)
    right = fd_merge(a, fd_merge(b, c))
    scale = np.linalg.norm(G, 2)
    np.testing.assert_allclose(np.asarray(fd_covariance(left)),
                               np.asarray(fd_covariance(right)),
                               atol=1e-3 * scale)
    np.testing.assert_allclose(np.asarray(fd_covariance(left)), G,
                               atol=1e-3 * scale)
    assert float(left.rho) < 1e-4 * scale
    assert float(right.rho) < 1e-4 * scale


def test_merge_rho_conservation():
    """rho_merged = rho_a + rho_b + rho_t >= rho_a + rho_b: carried masses
    are additive through the merge (Robust FD), never dropped."""
    d, ell = 24, 4
    a = _sketch(_stream(4, d, 60, decay=8.0), d, ell)
    b = _sketch(_stream(5, d, 60, decay=8.0), d, ell)
    m = fd_merge(a, b)
    assert float(m.rho) >= float(a.rho) + float(b.rho) - 1e-5
    # identity participant: merging with an empty sketch changes nothing
    e = fd_init(d, ell)
    m_id = fd_merge(a, e)
    np.testing.assert_allclose(np.asarray(fd_covariance(m_id)),
                               np.asarray(fd_covariance(a)), atol=1e-4)
    np.testing.assert_allclose(float(m_id.rho), float(a.rho), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), shards=st.integers(2, 5))
def test_merge_disjoint_shards_matches_stream_bound(seed, shards):
    """Sketching k disjoint shards locally and merging matches the exact
    union covariance within the FD guarantee (||G - cov|| <= rho), i.e. the
    merged sketch is as good as a single-stream sketch up to its own
    (additively carried) escaped mass."""
    d, ell, T = 24, 6, 20
    G = np.zeros((d, d))
    sketches = []
    for k in range(shards):
        stream = _stream(seed + k, d, T)
        G += sum(np.outer(g, g) for g in stream)
        sketches.append(_sketch(stream, d, ell))
    merged = sketches[0]
    for s in sketches[1:]:
        merged = fd_merge(merged, s)
    err = np.linalg.norm(G - np.asarray(fd_covariance(merged)), 2)
    assert err <= float(merged.rho) * (1 + 1e-4) + 1e-3
    # and the single-stream sketch of the concatenated stream is within the
    # two sketches' combined escaped mass of the merged one
    single = _sketch([g for k in range(shards)
                      for g in _stream(seed + k, d, T)], d, ell)
    cross = np.linalg.norm(np.asarray(fd_covariance(single)) -
                           np.asarray(fd_covariance(merged)), 2)
    assert cross <= (float(single.rho) + float(merged.rho)) * (1 + 1e-4) + 1e-3


def test_merge_batched_mirrors_single():
    """fd_merge_batched over a stack == fd_merge per block; the wire factor
    drops only the deflated zero column."""
    d, ell, N = 16, 5, 3
    rng = np.random.default_rng(7)
    mk = lambda s: _sketch([rng.normal(size=d) for _ in range(25)], d, ell)
    As, Bs = [mk(0) for _ in range(N)], [mk(1) for _ in range(N)]
    stack = lambda sts: FDState(
        eigvecs=jnp.stack([s.eigvecs for s in sts]),
        eigvals=jnp.stack([s.eigvals for s in sts]),
        rho=jnp.stack([s.rho for s in sts]))
    merged = fd_merge_batched(stack(As), stack(Bs))
    for n in range(N):
        one = fd_merge(As[n], Bs[n])
        np.testing.assert_allclose(
            np.asarray(fd_covariance(FDState(merged.eigvecs[n],
                                             merged.eigvals[n],
                                             merged.rho[n]))),
            np.asarray(fd_covariance(one)), atol=1e-3)
    B = fd_weighted_factor(stack(As), drop_deflated=True)
    assert B.shape == (N, d, ell - 1)
    full = fd_weighted_factor(stack(As))
    np.testing.assert_allclose(np.asarray(full[..., -1]), 0.0, atol=1e-5)


@pytest.mark.parametrize("exponent", [-0.25, -0.5, -1.0])
def test_inverse_root_apply_matches_dense(exponent):
    """(Gbar + (rho+eps)I)^p @ X via factored form == dense eigh result."""
    d, ell = 24, 6
    st_ = fd_init(d, ell)
    rng = np.random.default_rng(2)
    for g in _stream(3, d, 40):
        st_ = fd_update(st_, jnp.asarray(g, jnp.float32))
    eps = 1e-3
    X = jnp.asarray(rng.normal(size=(d, 5)), jnp.float32)
    got = fd_apply_inverse_root(st_, X, exponent=exponent, eps=eps)
    dense = np.asarray(fd_covariance(st_), np.float64) + \
        (float(st_.rho) + eps) * np.eye(d)
    lam, V = np.linalg.eigh(dense)
    want = (V * lam ** exponent) @ V.T @ np.asarray(X, np.float64)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-4)
