"""Kernel registry: backend resolution (env override, caching), uniform
KernelSet injection into sketchy AND shampoo, pooled-engine pallas-vs-xla
parity, and the no-vmap-of-kernel acceptance criterion."""
import inspect
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the deterministic sampling shim
    from hypothesis_compat import given, settings, strategies as st

from repro.core import api, pool
from repro.core.shampoo import ShampooConfig, shampoo
from repro.core.sketchy import SketchyConfig, sketchy
from repro.kernels import registry


# ------------------------------------------------------------------ resolution


def test_resolve_backend_defaults_and_validation(monkeypatch):
    monkeypatch.delenv(registry.ENV_VAR, raising=False)
    assert registry.resolve_backend("xla") == "xla"
    assert registry.resolve_backend("pallas") == "pallas"
    # auto on this (CPU) container resolves to xla
    assert registry.resolve_backend("auto") == \
        ("pallas" if registry.on_tpu() else "xla")
    with pytest.raises(ValueError, match="kernel backend"):
        registry.resolve_backend("cuda")


def test_env_override_forces_auto(monkeypatch):
    """REPRO_KERNEL_BACKEND overrides the platform default for "auto" (the
    benchmark/CI forcing hook); explicit requests always win."""
    monkeypatch.setenv(registry.ENV_VAR, "pallas")
    assert registry.resolve_backend("auto") == "pallas"
    assert registry.resolve_backend("xla") == "xla"
    monkeypatch.setenv(registry.ENV_VAR, "xla")
    assert registry.resolve_backend("auto") == "xla"
    monkeypatch.setenv(registry.ENV_VAR, "metal")
    with pytest.raises(ValueError, match=registry.ENV_VAR):
        registry.resolve_backend("auto")


def test_kernel_sets_are_interned(monkeypatch):
    """One KernelSet object per resolved backend (jit-cache friendly; the
    platform probe runs once, not per trace)."""
    monkeypatch.delenv(registry.ENV_VAR, raising=False)
    assert registry.get_kernels("xla") is registry.get_kernels("xla")
    assert registry.get_kernels("pallas") is registry.get_kernels("pallas")
    if not registry.on_tpu():
        assert registry.get_kernels("auto") is registry.get_kernels("xla")
    assert registry.get_kernels("xla").backend == "xla"
    assert registry.get_kernels("pallas").backend == "pallas"


def test_engine_validates_kernel_backend():
    with pytest.raises(ValueError, match="kernel_backend"):
        api.EngineConfig(kernel_backend="cuda")


# ----------------------------------------------------------- engine injection


def _params(seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    return {"m": mk(48, 20), "v": mk(10), "t": mk(3, 40, 24), "b": mk(70, 30),
            "m2": mk(48, 20)}


def _grad(seed):
    return _params(seed + 100)


@pytest.mark.parametrize("make_tx", [
    lambda backend: sketchy(SketchyConfig(rank=8, block_size=32, beta2=0.99,
                                          update_every=2,
                                          kernel_backend=backend)),
    lambda backend: shampoo(ShampooConfig(block_size=32, beta2=0.99,
                                          root_every=2,
                                          kernel_backend=backend)),
], ids=["sketchy", "shampoo"])
def test_pooled_engine_pallas_matches_xla(make_tx):
    """Acceptance criterion: the pooled engine with kernel_backend="pallas"
    (interpret mode on CPU) is allclose to the XLA path — for Sketchy AND
    Shampoo, which now shares the same batched-gram kernel path."""
    params = _params()
    tx_x, tx_p = make_tx("xla"), make_tx("pallas")
    s_x, s_p = tx_x.init(params), tx_p.init(params)
    for t in range(4):
        g = _grad(t)
        u_x, s_x = tx_x.update(g, s_x, params)
        u_p, s_p = tx_p.update(g, s_p, params)
        # tolerance: eigh amplifies f32 kernel-order noise (~1e-7 on the
        # Gram) into ~1e-4 relative differences on the refreshed sketch
        for a, b in zip(jax.tree.leaves(u_x), jax.tree.leaves(u_p)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=1e-3)


def test_engine_injects_kernels_uniformly():
    """Both kron-style preconditioners expose a ``kernels`` field the engine
    fills from EngineConfig.kernel_backend — no private per-optimizer flag."""
    from repro.core.shampoo import ShampooPreconditioner
    from repro.core.sketchy import SketchyPreconditioner

    ks = registry.get_kernels("pallas")
    for p in (SketchyPreconditioner(SketchyConfig()),
              ShampooPreconditioner(ShampooConfig())):
        assert p.kernels is None
        injected = api._inject_kernels(p, ks)
        assert injected.kernels is ks
        # explicit kernels win over the engine's choice
        assert api._inject_kernels(injected,
                                   registry.get_kernels("xla")).kernels is ks
    assert not hasattr(SketchyConfig(), "use_kernels")


def test_pooled_dispatch_uses_batched_entry_points():
    """Acceptance criterion: core/api.py never vmaps a single-block
    gram/lowrank kernel — sketchy/shampoo provide *_batched methods (the
    engine's preferred path) and the engine source only falls back to vmap
    for implementations without them."""
    from repro.core.shampoo import ShampooPreconditioner
    from repro.core.sketchy import SketchyPreconditioner

    for cls in (SketchyPreconditioner, ShampooPreconditioner):
        for name in ("update_stats", "refresh", "precondition"):
            assert hasattr(cls, name + "_batched"), (cls, name)
    # the engine may reference batched_gram/batched_lowrank_apply (the
    # sanctioned path) but never a bare single-block kernel name
    src = inspect.getsource(api)
    hit = re.search(r"(?<!batched_)(gram|lowrank)", src)
    assert hit is None, hit


# --------------------------------------- pack/engine dispatch round-trip (hyp)


@settings(max_examples=8, deadline=None)
@given(
    dims=st.lists(st.integers(3, 40), min_size=2, max_size=6),
    bs=st.sampled_from([8, 16]),
)
def test_engine_dispatch_roundtrip_pallas_vs_xla(dims, bs):
    """Property: for arbitrary mixed trees, packing through pool.pack and
    dispatching the batched Pallas kernels block-for-block agrees with the
    XLA path, and the packed pools keep the canonical layout."""
    rng = np.random.default_rng(0)
    shapes = [(dims[i], dims[i + 1]) for i in range(0, len(dims) - 1, 2)]
    shapes.append((dims[0],))        # a diag-fallback leaf
    params = [jnp.asarray(rng.normal(size=s), jnp.float32) for s in shapes]
    grads = [jnp.asarray(rng.normal(size=s), jnp.float32) for s in shapes]

    index = pool.build_index(tuple(shapes), bs)
    packed = pool.pack(index, grads)
    for grp in index.groups:
        assert packed[grp.key].shape == (grp.num_blocks, grp.bs_m, grp.bs_n)

    mk = lambda backend: sketchy(SketchyConfig(
        rank=4, block_size=bs, update_every=1, kernel_backend=backend))
    tx_x, tx_p = mk("xla"), mk("pallas")
    s_x, s_p = tx_x.init(params), tx_p.init(params)
    u_x, s_x = tx_x.update(grads, s_x, params)
    u_p, s_p = tx_p.update(grads, s_p, params)
    for a, b in zip(jax.tree.leaves(u_x), jax.tree.leaves(u_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-3)
    # pooled stats stay congruent across backends: same pool keys/shapes,
    # and the sign-invariant sketch pieces (eigvals, rho) agree — eigvec
    # columns are only defined up to sign under perturbation, so raw
    # eigvec comparison would flake
    assert set(s_x.pools) == set(s_p.pools)
    for key in s_x.pools:
        px, pp = api.untag(s_x.pools[key]), api.untag(s_p.pools[key])
        for a, b in zip(jax.tree.leaves(px), jax.tree.leaves(pp)):
            assert a.shape == b.shape
        for side in ("left", "right"):
            np.testing.assert_allclose(
                np.asarray(getattr(px, side).eigvals),
                np.asarray(getattr(pp, side).eigvals), rtol=1e-3, atol=1e-4)
            np.testing.assert_allclose(
                np.asarray(getattr(px, side).rho),
                np.asarray(getattr(pp, side).rho), rtol=1e-3, atol=1e-4)
