"""Per-kernel shape/dtype sweeps vs the pure-jnp ref oracles
(interpret=True executes the kernel body on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

import jax

from repro.kernels.flash.kernel import flash_attention_pallas
from repro.kernels.flash.ref import attention_ref
from repro.kernels.gram.kernel import batched_gram_pallas, gram_pallas
from repro.kernels.gram.ref import batched_gram_ref, gram_ref
from repro.kernels.lowrank.kernel import (batched_lowrank_apply_pallas,
                                          lowrank_apply_pallas)
from repro.kernels.lowrank.ref import (batched_lowrank_apply_ref,
                                       lowrank_apply_ref)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("d,k", [(16, 4), (64, 16), (100, 30), (257, 96),
                                 (1024, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_sweep(d, k, dtype):
    a = jnp.asarray(RNG.normal(size=(d, k)), dtype)
    got = gram_pallas(a, bk=32, bd=64)      # f32 accumulator result
    want = gram_ref(a)
    assert got.dtype == jnp.float32
    tol = 1e-4 * np.sqrt(d) * (1 if dtype == jnp.float32 else 10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=1e-5)


# odd pool sizes (N not a multiple of bn_stack), ragged d < bd and k < bk
@pytest.mark.parametrize("N,d,k,bn_stack", [(1, 16, 4, 1), (3, 20, 6, 2),
                                            (5, 100, 30, 2), (7, 33, 9, 3),
                                            (4, 64, 16, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_gram_sweep(N, d, k, bn_stack, dtype):
    a = jnp.asarray(RNG.normal(size=(N, d, k)), dtype)
    got = batched_gram_pallas(a, bk=16, bd=32, bn_stack=bn_stack)
    want = batched_gram_ref(a)
    # both paths accumulate in f32 whatever the input dtype
    assert got.dtype == jnp.float32
    assert want.dtype == jnp.float32
    assert got.shape == (N, k, k)
    tol = 1e-4 * np.sqrt(d) * (1 if dtype == jnp.float32 else 10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=1e-5)


def test_batched_gram_matches_vmapped_single_block():
    """The grid-over-N kernel == vmap of the single-block kernel (same tiled
    accumulation order per block), and the batched ref == vmap of the single
    ref bitwise — the pooled engine's bitwise-parity foundation."""
    a = jnp.asarray(RNG.normal(size=(5, 48, 12)), jnp.float32)
    batched = batched_gram_pallas(a, bk=8, bd=16, bn_stack=2)
    single = jnp.stack([gram_pallas(a[i], bk=8, bd=16)
                        for i in range(a.shape[0])])
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(single))
    np.testing.assert_array_equal(
        np.asarray(batched_gram_ref(a)),
        np.asarray(jax.vmap(gram_ref)(a)))


@pytest.mark.parametrize("d,ell,n", [(32, 4, 8), (64, 16, 64), (123, 17, 50),
                                     (1024, 256, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_sweep(d, ell, n, dtype):
    u = jnp.asarray(np.linalg.qr(RNG.normal(size=(d, d)))[0][:, :ell], dtype)
    g = jnp.asarray(RNG.normal(size=(d, n)), dtype)
    coeffs = jnp.asarray(RNG.random(ell), jnp.float32)
    got = lowrank_apply_pallas(u, coeffs, 0.31, g, bn=64)
    want = lowrank_apply_ref(u.astype(jnp.float32), coeffs, 0.31,
                             g.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.08
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


# odd (N, d, ell, n): N ragged against bn_stack, n ragged against bn
@pytest.mark.parametrize("N,d,ell,n,bn_stack", [(1, 32, 4, 8, 1),
                                                (3, 24, 6, 10, 2),
                                                (5, 64, 16, 33, 3),
                                                (7, 123, 17, 50, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_lowrank_sweep(N, d, ell, n, bn_stack, dtype):
    u = jnp.asarray(RNG.normal(size=(N, d, ell)), dtype)
    g = jnp.asarray(RNG.normal(size=(N, d, n)), dtype)
    coeffs = jnp.asarray(RNG.random((N, ell)), jnp.float32)
    base = jnp.asarray(RNG.random(N), jnp.float32)
    got = batched_lowrank_apply_pallas(u, coeffs, base, g, bn=16,
                                       bn_stack=bn_stack)
    want = batched_lowrank_apply_ref(u.astype(jnp.float32), coeffs, base,
                                     g.astype(jnp.float32))
    # output keeps g's dtype; the two matmuls accumulate in f32 (bf16 error
    # is output quantization, ~2^-8 relative, not accumulation drift)
    assert got.dtype == g.dtype
    assert got.shape == (N, d, n)
    rtol, atol = (1e-5, 1e-4) if dtype == jnp.float32 else (1e-2, 0.1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)


def test_batched_lowrank_matches_vmapped_single_block():
    N, d, ell, n = 4, 40, 8, 12
    u = jnp.asarray(RNG.normal(size=(N, d, ell)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(N, d, n)), jnp.float32)
    coeffs = jnp.asarray(RNG.random((N, ell)), jnp.float32)
    base = jnp.asarray(RNG.random(N), jnp.float32)
    batched = batched_lowrank_apply_pallas(u, coeffs, base, g, bn=8,
                                           bn_stack=2)
    single = jnp.stack([lowrank_apply_pallas(u[i], coeffs[i], base[i], g[i],
                                             bn=8) for i in range(N)])
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(single))
    np.testing.assert_array_equal(
        np.asarray(batched_lowrank_apply_ref(u, coeffs, base, g)),
        np.asarray(jax.vmap(lowrank_apply_ref)(u, coeffs, base, g)))


def test_public_ops_wrappers_dispatch_pallas():
    """kernels/*/ops.py are the always-Pallas public entry points (interpret
    mode resolved once via the registry) — single-block and batched."""
    from repro.kernels.gram import ops as gram_ops
    from repro.kernels.lowrank import ops as lowrank_ops

    a = jnp.asarray(RNG.normal(size=(3, 24, 6)), jnp.float32)
    np.testing.assert_allclose(np.asarray(gram_ops.gram(a[0])),
                               np.asarray(gram_ref(a[0])), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gram_ops.batched_gram(a)),
                               np.asarray(batched_gram_ref(a)), atol=1e-4)
    u = jnp.asarray(RNG.normal(size=(3, 24, 4)), jnp.float32)
    c = jnp.asarray(RNG.random((3, 4)), jnp.float32)
    b = jnp.asarray(RNG.random(3), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(3, 24, 5)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(lowrank_ops.lowrank_apply(u[0], c[0], b[0], g[0])),
        np.asarray(lowrank_apply_ref(u[0], c[0], b[0], g[0])), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(lowrank_ops.batched_lowrank_apply(u, c, b, g)),
        np.asarray(batched_lowrank_apply_ref(u, c, b, g)), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_gram_low_precision_accumulates_in_f32(dtype):
    """Satellite pin: half-precision inputs hit a f32 accumulator in both the
    single-block and batched kernels — outputs are f32 and (for a single d
    tile, where the tiled association matches) bitwise equal to the f32
    contraction of the rounded inputs."""
    a1 = jnp.asarray(RNG.normal(size=(24, 6)), dtype)
    got1 = gram_pallas(a1, bk=8, bd=32)           # d fits one bd tile
    assert got1.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got1),
                                  np.asarray(gram_ref(a1)))
    aN = jnp.asarray(RNG.normal(size=(3, 24, 6)), dtype)
    gotN = batched_gram_pallas(aN, bk=8, bd=32, bn_stack=2)
    assert gotN.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(gotN),
                                  np.asarray(batched_gram_ref(aN)))


def test_batched_kernels_empty_pool_group():
    """N=0 guard: every batched kernel short-circuits an empty pool stack
    (a 0-sized grid dim is undefined behaviour in some lowerings) and the
    ``min(bn_stack, max(N, 1))`` clamp keeps any requested stacking legal —
    shapes and dtypes must match the non-empty contract."""
    from repro.kernels.gram.kernel import batched_gram_mixed_pallas
    from repro.kernels.lowrank.kernel import batched_project_quantize_pallas

    d, ell, k, n = 16, 4, 3, 5
    a0 = jnp.zeros((0, d, k), jnp.float32)
    out = batched_gram_pallas(a0, bn_stack=8)
    assert out.shape == (0, k, k) and out.dtype == jnp.float32

    vq0 = jnp.zeros((0, d, ell), jnp.int8)
    colw0 = jnp.zeros((0, ell), jnp.float32)
    out = batched_gram_mixed_pallas(vq0, colw0, a0, bn_stack=8)
    assert out.shape == (0, ell + k, ell + k) and out.dtype == jnp.float32

    u0 = jnp.zeros((0, d, ell), jnp.float32)
    c0 = jnp.zeros((0, ell), jnp.float32)
    b0 = jnp.zeros((0,), jnp.float32)
    g0 = jnp.zeros((0, d, n), jnp.float32)
    out = batched_lowrank_apply_pallas(u0, c0, b0, g0, bn_stack=8)
    assert out.shape == (0, d, n) and out.dtype == jnp.float32

    wt0 = jnp.zeros((0, ell, ell), jnp.float32)
    wb0 = jnp.zeros((0, k, ell), jnp.float32)
    vals, scale = batched_project_quantize_pallas(vq0, wt0, a0, wb0,
                                                  bn_stack=8)
    assert vals.shape == (0, d, ell) and vals.dtype == jnp.int8
    assert scale.shape == (0, 1, 1) and scale.dtype == jnp.float32


@pytest.mark.parametrize("B,Hq,Hkv,S,hd,causal", [
    (1, 2, 2, 64, 16, True),
    (2, 4, 2, 96, 32, True),     # GQA + ragged tiles
    (1, 8, 1, 128, 64, True),    # MQA
    (2, 2, 2, 80, 16, False),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(B, Hq, Hkv, S, hd, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Hq, S, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, hd)), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, bq=32, bk=32)
    want = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,H,P,N,chunk,ht", [
    (1, 32, 4, 16, 16, 8, 4),
    (2, 64, 8, 16, 32, 16, 4),
    (1, 48, 6, 32, 64, 16, 2),   # ragged chunk/head tiling
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(B, S, H, P, N, chunk, ht, dtype):
    from repro.kernels.ssd.kernel import ssd_pallas
    from repro.kernels.ssd.ref import ssd_ref
    u = jnp.asarray(RNG.normal(size=(B, S, H, P)) * 0.5, dtype)
    dlog = jnp.asarray(-np.abs(RNG.normal(size=(B, S, H))) * 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)) * 0.3, dtype)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)) * 0.3, dtype)
    got = ssd_pallas(u, dlog, Bm, Cm, chunk=chunk, head_tile=ht)
    want = ssd_ref(u, dlog, Bm, Cm, chunk=chunk)
    tol = 5e-6 * S if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
