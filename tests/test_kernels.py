"""Per-kernel shape/dtype sweeps vs the pure-jnp ref oracles
(interpret=True executes the kernel body on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash.kernel import flash_attention_pallas
from repro.kernels.flash.ref import attention_ref
from repro.kernels.gram.kernel import gram_pallas
from repro.kernels.gram.ref import gram_ref
from repro.kernels.lowrank.kernel import lowrank_apply_pallas
from repro.kernels.lowrank.ref import lowrank_apply_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("d,k", [(16, 4), (64, 16), (100, 30), (257, 96),
                                 (1024, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_sweep(d, k, dtype):
    a = jnp.asarray(RNG.normal(size=(d, k)), dtype)
    got = gram_pallas(a, bk=32, bd=64)      # f32 accumulator result
    want = gram_ref(a)
    assert got.dtype == jnp.float32
    tol = 1e-4 * np.sqrt(d) * (1 if dtype == jnp.float32 else 10)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=1e-5)


@pytest.mark.parametrize("d,ell,n", [(32, 4, 8), (64, 16, 64), (123, 17, 50),
                                     (1024, 256, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lowrank_sweep(d, ell, n, dtype):
    u = jnp.asarray(np.linalg.qr(RNG.normal(size=(d, d)))[0][:, :ell], dtype)
    g = jnp.asarray(RNG.normal(size=(d, n)), dtype)
    coeffs = jnp.asarray(RNG.random(ell), jnp.float32)
    got = lowrank_apply_pallas(u, coeffs, 0.31, g, bn=64)
    want = lowrank_apply_ref(u.astype(jnp.float32), coeffs, 0.31,
                             g.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.08
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("B,Hq,Hkv,S,hd,causal", [
    (1, 2, 2, 64, 16, True),
    (2, 4, 2, 96, 32, True),     # GQA + ragged tiles
    (1, 8, 1, 128, 64, True),    # MQA
    (2, 2, 2, 80, 16, False),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(B, Hq, Hkv, S, hd, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Hq, S, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, hd)), dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, bq=32, bk=32)
    want = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,H,P,N,chunk,ht", [
    (1, 32, 4, 16, 16, 8, 4),
    (2, 64, 8, 16, 32, 16, 4),
    (1, 48, 6, 32, 64, 16, 2),   # ragged chunk/head tiling
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(B, S, H, P, N, chunk, ht, dtype):
    from repro.kernels.ssd.kernel import ssd_pallas
    from repro.kernels.ssd.ref import ssd_ref
    u = jnp.asarray(RNG.normal(size=(B, S, H, P)) * 0.5, dtype)
    dlog = jnp.asarray(-np.abs(RNG.normal(size=(B, S, H))) * 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)) * 0.3, dtype)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)) * 0.3, dtype)
    got = ssd_pallas(u, dlog, Bm, Cm, chunk=chunk, head_tile=ht)
    want = ssd_ref(u, dlog, Bm, Cm, chunk=chunk)
    tol = 5e-6 * S if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
