"""Per-arch smoke tests (reduced configs): forward shapes, no NaNs, one
train step, scan-vs-unroll equivalence, prefill-vs-decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, get_reduced
from repro.core.factory import OptimizerConfig, make_optimizer
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.train.trainer import make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _batch(cfg, key=KEY, batch=B, seq=S):
    out = {}
    if cfg.embed_inputs:
        shape = (batch, seq, cfg.num_codebooks) if cfg.num_codebooks \
            else (batch, seq)
        out["tokens"] = jax.random.randint(key, shape, 0, cfg.vocab_size)
        out["labels"] = jax.random.randint(key, shape, 0, cfg.vocab_size)
    else:
        out["embeds"] = 0.1 * jax.random.normal(
            key, (batch, seq, cfg.d_model), jnp.float32)
        out["labels"] = jax.random.randint(key, (batch, seq), 0,
                                           cfg.vocab_size)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    params = model_lib.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits = model_lib.forward(cfg, params, batch)
    expect = (B, S, cfg.num_codebooks, cfg.vocab_size) if cfg.num_codebooks \
        else (B, S, cfg.vocab_size)
    assert logits.shape == expect
    assert not bool(jnp.isnan(logits).any())

    tx = make_optimizer(OptimizerConfig(
        name="sketchy", learning_rate=1e-2, rank=8, block_size=32,
        update_every=1, total_steps=10, schedule="constant"))
    # donate=False: the delta check below reads `params` after the step
    step = jax.jit(make_train_step(cfg, tx, donate=False))
    state = tx.init(params)
    p2, state, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_unroll_equivalence(arch):
    cfg = get_reduced(arch)
    params = model_lib.init_params(cfg, KEY)
    batch = _batch(cfg)
    a = model_lib.forward(cfg, params, batch, unroll=False)
    b = model_lib.forward(cfg, params, batch, unroll=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-4)


@pytest.mark.parametrize("arch", ["paper_lm_100m", "gemma_2b", "mamba2_370m",
                                  "zamba2_7b", "deepseek_moe_16b",
                                  "musicgen_large"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits == full forward logits at each position."""
    cfg = get_reduced(arch)
    params = model_lib.init_params(cfg, KEY)
    seq = 8
    batch = _batch(cfg, batch=1, seq=seq)
    full = np.asarray(model_lib.forward(cfg, params, batch), np.float32)

    cache = cache_lib.init_cache(cfg, 1, seq)
    toks = batch["tokens"]
    step_fn = jax.jit(
        lambda p, c, b, pos: cache_lib.decode_step(cfg, p, c, b, pos))
    for t in range(seq):
        db = {"token": toks[:, t:t + 1]}
        logits, cache = step_fn(params, cache, db, jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   full[:, t], rtol=5e-3, atol=5e-3)


def test_decode_matches_forward_vlm():
    cfg = get_reduced("qwen2_vl_72b")
    params = model_lib.init_params(cfg, KEY)
    seq = 6
    batch = _batch(cfg, batch=1, seq=seq)
    full = np.asarray(model_lib.forward(cfg, params, batch), np.float32)
    cache = cache_lib.init_cache(cfg, 1, seq)
    step_fn = jax.jit(
        lambda p, c, b, pos: cache_lib.decode_step(cfg, p, c, b, pos))
    for t in range(seq):
        db = {"embed": batch["embeds"][:, t:t + 1]}
        logits, cache = step_fn(params, cache, db, jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                                   full[:, t], rtol=5e-3, atol=5e-3)


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters."""
    c = get_config("qwen2-vl-72b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    assert c.mrope
    c = get_config("zamba2-7b")
    assert (c.num_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = get_config("kimi-k2-1t-a32b")
    assert (c.num_experts, c.experts_per_token, c.vocab_size) == (384, 8, 163840)
    c = get_config("deepseek-moe-16b")
    assert (c.num_experts, c.experts_per_token, c.num_shared_experts) == (64, 6, 2)
    c = get_config("gemma-2b")
    assert (c.num_kv_heads, c.head_dim, c.vocab_size) == (1, 256, 256000)
    c = get_config("mamba2-370m")
    assert (c.ssm_state, c.num_layers, c.d_model) == (128, 48, 1024)
    c = get_config("musicgen-large")
    assert (c.num_codebooks, c.vocab_size) == (4, 2048)
    c = get_config("qwen3-32b")
    assert c.qk_norm and c.num_heads == 64
    c = get_config("qwen2.5-32b")
    assert c.qkv_bias and c.d_ff == 27648
    c = get_config("phi3-mini-3.8b")
    assert c.num_layers == 32 and c.d_ff == 8192
