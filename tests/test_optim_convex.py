"""Convex OCO behaviour (paper Appendix A + the Observation 2 mechanism)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sadagrad as oco
from repro.core.fd import fd_init, fd_update


def _run(name, gs, losses_of, lr, d, ell=6, delta=1e-3):
    init, step, needs = oco.LEARNERS[name]
    state = init(d, ell) if needs["ell"] else init(d)
    x = jnp.zeros((d,))
    total = 0.0
    for g_fn, loss_fn in zip(gs, losses_of):
        total += float(loss_fn(x))
        g = g_fn(x)
        if needs["delta"]:
            x, state = step(state, x, g, lr, delta)
        else:
            x, state = step(state, x, g, lr)
    return total if np.isfinite(total) else np.inf


def _logistic_stream(seed, d, T, rank=None):
    """Synthetic binary logistic regression stream."""
    rng = np.random.default_rng(seed)
    if rank:
        basis = np.linalg.qr(rng.normal(size=(d, rank)))[0]
        feats = rng.normal(size=(T, rank)) @ basis.T
    else:
        feats = rng.normal(size=(T, d)) * np.exp(-np.arange(d) / 8.0)
    w_star = rng.normal(size=d)
    labels = np.sign(feats @ w_star + 0.1 * rng.normal(size=T))
    gs, ls = [], []
    for a, y in zip(feats, labels):
        a_j = jnp.asarray(a * y, jnp.float32)

        def loss(x, a_j=a_j):
            return jnp.log1p(jnp.exp(-a_j @ x))

        gs.append(jax.grad(loss))
        ls.append(loss)
    return gs, ls


def test_sadagrad_competitive_on_decaying_spectrum():
    """Paper Tbl. 3: S-AdaGrad places with the top full-information
    baselines despite O(d*ell) covariance memory."""
    d, T = 30, 300
    gs, ls = _logistic_stream(0, d, T)
    lrs = (0.02, 0.05, 0.2, 0.5, 1.0)
    best = {}
    for name in ("s-adagrad", "adagrad", "ogd"):
        best[name] = min(_run(name, gs, ls, lr, d) for lr in lrs)
    assert best["s-adagrad"] <= 1.15 * min(best.values())


def test_obs2_escaped_mass_mechanism():
    """Obs. 2 mechanism: on iid draws from r > ell orthonormal vectors the FD
    escaped mass grows LINEARLY in T (what makes Ada-FD's fixed-delta bound
    Omega(T^{3/4})), while on a fast-decaying stream it grows sublinearly."""
    d, r, ell = 24, 12, 6
    rng = np.random.default_rng(3)
    W = np.linalg.qr(rng.normal(size=(d, r)))[0].T

    def rho_at(T, stream):
        st = fd_init(d, ell)
        for g in stream(T):
            st = fd_update(st, jnp.asarray(g, jnp.float32))
        return float(st.rho)

    def orth_stream(T):
        return [W[i] for i in rng.integers(0, r, size=T)]

    def decay_stream(T):
        scales = np.exp(-np.arange(d) / 2.0)
        return [scales * rng.normal(size=d) for _ in range(T)]

    r1, r2 = rho_at(150, orth_stream), rho_at(300, orth_stream)
    # linear growth: doubling T roughly doubles rho
    assert r2 >= 1.6 * r1
    d1, d2 = rho_at(150, decay_stream), rho_at(300, decay_stream)
    # decaying spectrum: clearly sublinear vs the orthonormal stream
    assert (d2 / max(d1, 1e-9)) < (r2 / r1)


def test_sadagrad_consistently_top3():
    """Paper Tbl. 3's actual claim: S-AdaGrad is the only method that
    consistently places in the top 3 across datasets."""
    lrs = (0.02, 0.05, 0.2, 0.5)
    deltas = (1e-4, 1e-2, 1.0)
    for seed, rank in ((0, None), (5, 12)):
        d, T = 24, 250
        gs, ls = _logistic_stream(seed, d, T, rank=rank)
        results = {}
        for name in ("s-adagrad", "adagrad", "ogd", "ada-fd", "fd-son",
                     "rfd-son"):
            needs = oco.LEARNERS[name][2]
            results[name] = min(
                _run(name, gs, ls, lr, d, ell=10, delta=delta)  # paper: l=10
                for lr in lrs
                for delta in (deltas if needs["delta"] else (1e-3,)))
        order = sorted(results, key=results.get)
        assert order.index("s-adagrad") < 3, (order, results)


def test_all_learners_run():
    d, T = 16, 50
    gs, ls = _logistic_stream(1, d, T)
    for name in oco.LEARNERS:
        total = _run(name, gs, ls, 0.01, d, ell=4, delta=0.1)
        assert np.isfinite(total), name
