"""Block-pool engine: pack/unpack round-trip properties, bitwise parity with
the PR-1 per-leaf engine (synchronized refresh), staggered-refresh window
coverage, and the pre-pool checkpoint migration shim."""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the deterministic sampling shim
    from hypothesis_compat import given, settings, strategies as st

import reference_impls as ref
from repro.core import api, blocking, pool
from repro.core.sadagrad import SAdaGradPreconditioner, sadagrad_init, \
    sadagrad_step
from repro.core.shampoo import ShampooConfig, ShampooPreconditioner
from repro.core.sketchy import SketchyConfig, SketchyPreconditioner, sketchy


def _params(seed=0):
    """Matrix, vector, >2D stack (scan/MoE), padded-tile, and shape-duplicate
    leaves — every packing case at once."""
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    return {"m": mk(48, 20), "v": mk(10), "t": mk(3, 40, 24), "b": mk(70, 30),
            "m2": mk(48, 20)}


def _grad(seed):
    return _params(seed + 100)


# ---------------------------------------------------------------- pack/unpack


@settings(max_examples=25, deadline=None)
@given(
    dims=st.lists(st.integers(1, 70), min_size=2, max_size=8),
    lead=st.lists(st.integers(1, 3), min_size=0, max_size=2),
    bs=st.sampled_from([8, 16, 32]),
)
def test_pack_unpack_roundtrip(dims, lead, bs):
    """unpack(pack(leaves)) == leaves exactly, for arbitrary mixed trees
    (padded tiles, stacked/MoE leading dims, vectors, duplicates)."""
    rng = np.random.default_rng(0)
    shapes = []
    for i in range(0, len(dims) - 1, 2):
        shape = (dims[i], dims[i + 1])
        if lead and i % 4 == 0:       # give some leaves stacked leading dims
            shape = tuple(lead) + shape
        shapes.append(shape)
    shapes.append((dims[0],))         # always include a vector (diag) leaf
    leaves = [jnp.asarray(rng.normal(size=s), jnp.float32) for s in shapes]

    index = pool.build_index(tuple(shapes), bs)
    packed = pool.pack(index, leaves)

    # group invariants: stack shapes, contiguous offsets, full coverage
    total = 0
    for gi, grp in enumerate(index.groups):
        assert packed[grp.key].shape == (grp.num_blocks, grp.bs_m, grp.bs_n)
        offset = 0
        for j in grp.leaf_ids:
            plan = index.leaves[j]
            assert plan.group == gi and plan.offset == offset
            offset += plan.info.num_blocks
        assert offset == grp.num_blocks
        total += grp.num_blocks
    assert total == index.total_blocks
    assert total == sum(p.info.num_blocks for p in index.leaves
                        if p.group is not None)

    out = pool.unpack(index, packed)
    for x, back, plan in zip(leaves, out, index.leaves):
        if plan.group is None:
            assert back is None and plan.info.kind == "diag"
        else:
            np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_group_key_collation_matches_dict_pytree_order():
    """Pool dict keys are sorted at build time so the PoolIndex group order
    matches jax's sorted-dict flatten order (checkpoint/sharding alignment)."""
    shapes = ((48, 20), (70, 30), (3, 40, 24), (48, 20))
    index = pool.build_index(shapes, 32)
    keys = [g.key for g in index.groups]
    assert keys == sorted(keys)
    assert keys == [pool.group_key(g.bs_m, g.bs_n) for g in index.groups]


def test_build_index_is_cached():
    a = pool.build_index(((48, 20), (10,)), 32)
    b = pool.build_index(((48, 20), (10,)), 32)
    assert a is b


# ------------------------------------------------------------- bitwise parity


def _parity_case(name):
    if name == "sketchy":
        cfg = SketchyConfig(rank=8, block_size=32, beta2=0.99, update_every=2,
                            start_preconditioning_step=2)
        precond = SketchyPreconditioner(cfg)
        ecfg = api.EngineConfig(block_size=32, beta2=0.99, update_every=2,
                                start_preconditioning_step=2)
    elif name == "shampoo":
        cfg = ShampooConfig(block_size=32, beta2=0.99, root_every=2)
        precond = ShampooPreconditioner(cfg)
        ecfg = api.EngineConfig(block_size=32, beta2=0.99, update_every=2)
    else:  # sadagrad
        precond = SAdaGradPreconditioner(8)
        ecfg = api.EngineConfig(block_size=1 << 30, beta2=1.0, update_every=1,
                                graft="none", treat_vectors_as_columns=True)
    return precond, ecfg


@pytest.mark.parametrize("name", ["sketchy", "shampoo", "sadagrad"])
def test_pooled_engine_bitwise_matches_per_leaf(name):
    """Acceptance criterion: under refresh_schedule="synchronized" the pooled
    engine is BITWISE identical (directions and statistics) to the PR-1
    per-leaf engine it replaces."""
    precond, ecfg = _parity_case(name)
    params = _params() if name != "sadagrad" else \
        {"x": jnp.asarray(np.random.default_rng(0).normal(size=32),
                          jnp.float32)}
    new_tx = api.scale_by_preconditioner(precond, ecfg)
    old_tx = ref.per_leaf_scale_by_preconditioner(precond, ecfg)
    s_new, s_old = new_tx.init(params), old_tx.init(params)
    for t in range(6):
        g = jax.tree.map(
            lambda p: jnp.asarray(
                np.random.default_rng(t).normal(size=p.shape), jnp.float32),
            params)
        u_new, s_new = new_tx.update(g, s_new, params)
        u_old, s_old = old_tx.update(g, s_old, params)
        for a, b in zip(jax.tree.leaves(u_new), jax.tree.leaves(u_old)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # statistics too: re-slice each leaf's block stack out of its pool
    index = pool.build_index(
        tuple(tuple(p.shape) for p in jax.tree.leaves(params)),
        ecfg.block_size, vectors_as_columns=ecfg.treat_vectors_as_columns)
    for j, (plan, old_leaf) in enumerate(zip(index.leaves, s_old.leaves)):
        if plan.group is None:
            np.testing.assert_array_equal(
                np.asarray(s_new.leaves[j].stats.value),
                np.asarray(old_leaf.stats))
            continue
        key = index.groups[plan.group].key
        sliced = jax.tree.map(
            lambda x: x[plan.offset:plan.offset + plan.info.num_blocks],
            api.untag(s_new.pools[key]))
        for a, b in zip(jax.tree.leaves(sliced),
                        jax.tree.leaves(old_leaf.stats)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pool_state_compiles_once_per_shape_group():
    """The tentpole: >=100 same-shaped leaves produce ONE pool group (one
    kernel set), not one per leaf — and the update still runs under jit."""
    rng = np.random.default_rng(0)
    params = {f"w{i:03d}": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
              for i in range(100)}
    tx = sketchy(SketchyConfig(rank=4, block_size=16, update_every=2))
    state = tx.init(params)
    assert list(state.pools) == ["16x16"]
    (stats_leaf, *_) = jax.tree.leaves(api.pool_stats(state))
    assert stats_leaf.shape[0] == 100   # pooled dim spans the whole model
    g = {k: jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
         for k in params}
    u, state = jax.jit(tx.update)(g, state)
    assert not any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(u))


# ---------------------------------------------------------- staggered refresh


def test_staggered_refreshes_each_block_once_per_window():
    """After the count-0 warm refresh, every block refreshes exactly once per
    update_every window and no step refreshes more than ceil(N/k) blocks
    (no global eigh spike in steady state)."""
    k = 3
    params = _params()
    tx = sketchy(SketchyConfig(rank=8, block_size=32, beta2=0.99,
                               update_every=k, refresh_schedule="staggered"))
    state = tx.init(params)
    # count 0: cold-start warm refresh touches EVERY block (same cost as the
    # synchronized schedule's first step) so no block preconditions with
    # zero-initialized stats
    u, state = tx.update(_grad(99), state, params)
    prev = {key: np.asarray(jax.tree.leaves(api.untag(v))[1])  # eigvals
            for key, v in state.pools.items()}
    for key, p in prev.items():
        assert not np.allclose(p, 0.0)   # warm refresh happened
    refresh_counts = {key: np.zeros(p.shape[0], np.int64)
                      for key, p in prev.items()}
    per_step_max = 0
    steps = 3 * k
    for t in range(steps):
        g = _grad(t)
        u, state = tx.update(g, state, params)
        changed_this_step = 0
        for key, v in state.pools.items():
            cur = np.asarray(jax.tree.leaves(api.untag(v))[1])
            changed = ~np.all(np.isclose(cur, prev[key]), axis=1)
            refresh_counts[key] += changed
            changed_this_step += int(changed.sum())
            prev[key] = cur
        per_step_max = max(per_step_max, changed_this_step)
    total_blocks = sum(len(c) for c in refresh_counts.values())
    # exactly once per window for every block, spike bounded by sum of
    # per-group capacities ceil(N/k)
    for key, counts in refresh_counts.items():
        np.testing.assert_array_equal(counts, steps // k)
    cap = sum(-(-len(c) // k) for c in refresh_counts.values())
    assert per_step_max <= cap < total_blocks


def test_synchronized_default_spikes_on_boundary():
    """Parity default: all blocks refresh together on count % k == 0."""
    k = 3
    params = _params()
    tx = sketchy(SketchyConfig(rank=8, block_size=32, beta2=0.99,
                               update_every=k))
    state = tx.init(params)
    prev = None
    changed_steps = []
    for t in range(2 * k + 1):
        u, state = tx.update(_grad(t), state, params)
        cur = np.asarray(jax.tree.leaves(api.pool_stats(state, "32x20"))[1])
        if prev is not None:
            changed_steps.append(not np.allclose(cur, prev))
        prev = cur.copy()
    # refreshes at counts 0, k, 2k -> changes visible at t=k and t=2k
    assert changed_steps == [t % k == k - 1 for t in range(2 * k)]


def test_refresh_schedule_validated():
    with pytest.raises(ValueError, match="refresh_schedule"):
        api.EngineConfig(refresh_schedule="sometimes")


def test_staggered_sadagrad_full_window_equivalence():
    """update_every=1 degenerates both schedules to refresh-every-step, and
    the OCO learner stays bitwise stable under the pooled layout."""
    x1, st1 = jnp.zeros((16,)), sadagrad_init(16, 4)
    rng = np.random.default_rng(0)
    for t in range(5):
        g = jnp.asarray(rng.normal(size=16), jnp.float32)
        x1, st1 = sadagrad_step(st1, x1, g, 0.1)
    assert np.isfinite(np.asarray(x1)).all()
    assert st1.sketch.eigvecs.shape == (16, 4)


# --------------------------------------------------------- diag_eps satellite


def test_diag_eps_decoupled_from_graft_eps():
    """diag_eps=None keeps the historic graft_eps coupling (parity); setting
    it changes only the diagonal-fallback leaves."""
    params = _params()
    g = _grad(0)
    base = sketchy(SketchyConfig(rank=8, block_size=32, update_every=1))
    same = sketchy(SketchyConfig(rank=8, block_size=32, update_every=1,
                                 diag_eps=1e-8))   # == default graft_eps
    loose = sketchy(SketchyConfig(rank=8, block_size=32, update_every=1,
                                  diag_eps=1e-2))
    u0, _ = base.update(g, base.init(params), params)
    u1, _ = same.update(g, same.init(params), params)
    u2, _ = loose.update(g, loose.init(params), params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(u0[k]), np.asarray(u1[k]))
    # only the vector (diag-fallback) leaf responds to diag_eps
    assert not np.allclose(np.asarray(u0["v"]), np.asarray(u2["v"]))
    for k in ("m", "t", "b", "m2"):
        np.testing.assert_array_equal(np.asarray(u0[k]), np.asarray(u2[k]))


# ------------------------------------------------- checkpoint migration shim


def _synthesize_pre_pool_state(state, params, block_size):
    """Re-slice a pooled engine state into the PR-1 per-leaf layout (tagged),
    as an old checkpoint would have stored it."""
    OldState = collections.namedtuple("OldState", ["count", "leaves"])
    OldLeaf = collections.namedtuple("OldLeaf", ["stats", "graft"])
    index = pool.build_index(
        tuple(tuple(p.shape) for p in jax.tree.leaves(params)), block_size)
    leaves = []
    for i, plan in enumerate(index.leaves):
        leaf = state.leaves[i]
        if plan.group is None:
            leaves.append(OldLeaf(stats=leaf.stats, graft=None))
            continue
        key = index.groups[plan.group].key
        sliced = jax.tree.map(
            lambda t: api.Tagged(
                t.value[plan.offset:plan.offset + plan.info.num_blocks],
                t.meta),
            state.pools[key], is_leaf=lambda x: isinstance(x, api.Tagged))
        leaves.append(OldLeaf(stats=sliced, graft=leaf.graft))
    return OldState(count=state.count, leaves=tuple(leaves))


def test_checkpoint_migrates_pre_pool_layout(tmp_path):
    from repro.train import checkpoint as ckpt

    params = _params()
    tx = sketchy(SketchyConfig(rank=8, block_size=32, beta2=0.99,
                               update_every=2))
    state = tx.init(params)
    u, state = tx.update(_grad(0), state, params)
    old = _synthesize_pre_pool_state(state, params, 32)

    d = str(tmp_path)
    ckpt.save(d, 11, {"opt": {"precond": old}})
    restored, step, _ = ckpt.restore(d, {"opt": {"precond": tx.init(params)}})
    assert step == 11
    got = api.leaves_with_meta(restored["opt"]["precond"])
    want = api.leaves_with_meta(state)
    assert len(got) == len(want)
    for (mg, a), (mw, b) in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_migration_rejects_incompatible(tmp_path):
    """A pre-pool checkpoint from a different optimizer family fails loudly
    instead of silently regrouping."""
    from repro.core.shampoo import shampoo
    from repro.train import checkpoint as ckpt

    params = _params()
    sk = sketchy(SketchyConfig(rank=8, block_size=32, update_every=2))
    old = _synthesize_pre_pool_state(sk.init(params), params, 32)
    d = str(tmp_path)
    ckpt.save(d, 0, {"opt": {"precond": old}})
    sh = shampoo(ShampooConfig(block_size=32))
    with pytest.raises(ValueError):
        ckpt.restore(d, {"opt": {"precond": sh.init(params)}})
