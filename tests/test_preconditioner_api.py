"""Unified Preconditioner API: update-for-update parity with the seed
monoliths, metadata-driven sharding + checkpointing, hyperparams-in-state."""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import reference_impls as ref
from repro.core import api, schedules, transform
from repro.core.adam import AdamConfig, adam
from repro.core.factory import OptimizerConfig, make_optimizer
from repro.core.shampoo import ShampooConfig, shampoo
from repro.core.sketchy import SketchyConfig, sketchy


def _params(seed=0):
    """Matrix, vector, >2D stack, and blocked (bigger than block_size) leaves."""
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    return {"m": mk(48, 20), "v": mk(10), "t": mk(3, 40, 24), "b": mk(70, 30)}


def _grad(seed):
    return _params(seed + 100)


def _assert_tree_close(a, b, **kw):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


@pytest.mark.parametrize("name", ["sketchy", "shampoo", "adam"])
def test_engine_matches_seed_direction(name):
    """The scale_by_preconditioner re-expression produces numerically
    identical updates to the seed monolith, across leaf kinds and steps
    (including update_every gating and start_preconditioning_step)."""
    if name == "sketchy":
        new_tx = sketchy(SketchyConfig(rank=8, block_size=32, beta2=0.99,
                                       update_every=2,
                                       start_preconditioning_step=2))
        old_tx = ref.seed_sketchy(SketchyConfig(rank=8, block_size=32,
                                                beta2=0.99, update_every=2,
                                                start_preconditioning_step=2))
    elif name == "shampoo":
        new_tx = shampoo(ShampooConfig(block_size=32, beta2=0.99,
                                       root_every=2))
        old_tx = ref.seed_shampoo(ShampooConfig(block_size=32, beta2=0.99,
                                                root_every=2))
    else:
        new_tx = adam(AdamConfig())
        old_tx = ref.seed_adam(AdamConfig())

    params = _params()
    s_new, s_old = new_tx.init(params), old_tx.init(params)
    for t in range(5):
        g = _grad(t)
        u_new, s_new = new_tx.update(g, s_new, params)
        u_old, s_old = old_tx.update(g, s_old, params)
        _assert_tree_close(u_new, u_old, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", ["sketchy", "shampoo", "adam"])
def test_factory_chain_matches_seed_chain(name):
    """Full make_optimizer chain (named_chain + inject_hyperparams) ==
    seed chain (tuple chain + scale_by_schedule), update for update."""
    cfg = OptimizerConfig(name=name, learning_rate=3e-3, total_steps=20,
                          rank=8, block_size=32, update_every=2,
                          weight_decay=1e-4, schedule="warmup_cosine")
    new_tx = make_optimizer(cfg)

    if name == "sketchy":
        direction = ref.seed_sketchy(SketchyConfig(
            rank=cfg.rank, block_size=cfg.block_size, beta2=cfg.beta2,
            update_every=cfg.update_every))
    elif name == "shampoo":
        direction = ref.seed_shampoo(ShampooConfig(
            block_size=cfg.block_size, beta2=cfg.beta2,
            root_every=cfg.update_every))
    else:
        direction = ref.seed_adam(AdamConfig(beta1=cfg.beta1,
                                             beta2=cfg.beta2))
    sched = schedules.warmup_cosine(cfg.learning_rate, cfg.total_steps,
                                    cfg.warmup_frac)
    parts = [transform.clip_by_global_norm(cfg.grad_clip), direction]
    if name != "adam":
        parts.append(transform.momentum(cfg.beta1, ema=True))
    parts.append(transform.add_decayed_weights(cfg.weight_decay))
    parts.append(transform.scale_by_schedule(lambda c: -sched(c)))
    old_tx = transform.chain(*parts)

    params = _params()
    s_new, s_old = new_tx.init(params), old_tx.init(params)
    for t in range(6):
        g = _grad(t)
        u_new, s_new = new_tx.update(g, s_new, params)
        u_old, s_old = old_tx.update(g, s_old, params)
        _assert_tree_close(u_new, u_old, rtol=1e-5, atol=1e-7)


def test_no_isinstance_dispatch_in_consumers():
    """Acceptance criterion: consumers walk StateMeta, not optimizer types."""
    from repro.core import factory
    from repro.train import trainer
    for mod in (factory, trainer):
        src = inspect.getsource(mod)
        for marker in ("SketchyState", "ShampooState", "AdamState",
                       "MatrixLeafState", "ShampooMatrixLeaf",
                       "DiagLeafState", "TraceState"):
            assert marker not in src, (mod.__name__, marker)


def test_state_meta_annotations_present():
    """Every engine state leaf is tagged; roles cover the expected set."""
    tx = make_optimizer(OptimizerConfig(name="sketchy", rank=8, block_size=32,
                                        update_every=2, weight_decay=1e-4,
                                        schedule="constant"))
    state = tx.init(_params())
    roles = {m.role for m, _ in api.leaves_with_meta(state) if m is not None}
    assert {"second_moment", "grafting", "momentum", "count",
            "hyperparam"} <= roles
    # second-moment accounting visible through any nesting, exact per-leaf:
    # matrix leaves: two FD sketches each (U, s, rho) per side
    sk = sketchy(SketchyConfig(rank=8, block_size=32))
    b = api.second_moment_bytes(sk.init({"w": jnp.zeros((64, 64))}))
    assert b == 4 * 2 * (32 * 8 + 8 + 1) * 4  # 4 blocks of 32, 2 sides each


def test_train_state_shardings_via_metadata():
    from repro.sharding import rules as rules_lib
    from repro.train import trainer

    mesh = jax.make_mesh((1,), ("data",))
    tx = make_optimizer(OptimizerConfig(name="sketchy", rank=8, block_size=32,
                                        update_every=2, schedule="constant"))
    params = _params()
    state = tx.init(params)
    with rules_lib.use_mesh(mesh) as rules:
        sh = trainer.train_state_shardings(state, params, rules)

    state_leaves = api.leaves_with_meta(state)
    sh_leaves = api.leaves_with_meta(sh)
    assert len(state_leaves) == len(sh_leaves)
    from jax.sharding import NamedSharding
    for (meta, leaf), (meta_sh, s) in zip(state_leaves, sh_leaves):
        assert isinstance(s, NamedSharding), (meta, s)
        assert meta_sh == meta
        if meta is not None and meta.role in ("count", "hyperparam"):
            assert s.spec == jax.sharding.PartitionSpec()
        if meta is not None and meta.blocked:
            # leading (blocks) dim sharded over the data axis when divisible
            assert s.spec[0] in ("data", ("data",)) or s.spec[0] is None
    # blocked FD leaves actually get the blocks-dim sharding on this mesh
    blocked = [s for (m, _), (_, s) in zip(state_leaves, sh_leaves)
               if m is not None and m.blocked]
    assert blocked and all(s.spec[0] is not None for s in blocked)

    # param-shaped leaves (momentum/grafting) inherit the param sharding
    with rules_lib.use_mesh(mesh) as rules:
        psh = rules_lib.tree_param_shardings(params, rules)
    flat_psh = jax.tree.leaves(
        psh, is_leaf=lambda x: isinstance(x, NamedSharding))
    for (m, _), (_, s) in zip(state_leaves, sh_leaves):
        if m is not None and m.role in ("momentum", "grafting"):
            assert s == flat_psh[m.param_index]


def test_checkpoint_roundtrip_with_state_meta(tmp_path):
    from repro.train import checkpoint as ckpt

    tx = make_optimizer(OptimizerConfig(name="sketchy", rank=8, block_size=32,
                                        update_every=2, weight_decay=1e-4,
                                        schedule="constant"))
    params = _params()
    state = tx.init(params)
    u, state = tx.update(_grad(0), state, params)

    d = str(tmp_path)
    ckpt.save(d, 3, {"params": params, "opt": state})
    # manifest records roles from StateMeta
    import json, os
    manifest = json.load(open(os.path.join(d, "step-3", "manifest.json")))
    roles = {rec["meta"]["role"] for rec in manifest["leaves"]
             if rec.get("meta")}
    assert {"second_moment", "grafting", "momentum", "count",
            "hyperparam"} <= roles

    template = {"params": _params(7), "opt": tx.init(_params(7))}
    restored, step, _ = ckpt.restore(d, template)
    assert step == 3
    _assert_tree_close(restored["opt"], state, rtol=0, atol=0)


def test_checkpoint_rejects_role_mismatch(tmp_path):
    from repro.train import checkpoint as ckpt
    d = str(tmp_path)
    arr = jnp.ones((4,))
    ckpt.save(d, 0, {"a": api.tag(arr, "momentum")})
    with pytest.raises(ValueError, match="state-role mismatch"):
        ckpt.restore(d, {"a": api.tag(arr, "second_moment")})


def test_inject_hyperparams_runtime_mutation():
    """lr lives in state: mutate it with set_hyperparams, no chain rebuild,
    same jitted update function."""
    tx = make_optimizer(OptimizerConfig(name="adam", learning_rate=1e-2,
                                        schedule="constant", grad_clip=None))
    params = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 0.5)}
    upd = jax.jit(tx.update)

    s0 = tx.init(params)
    u1, s1 = upd(g, s0, params)
    s1b = api.set_hyperparams(s1, learning_rate=2e-2)
    assert float(api.get_hyperparams(s1b)["learning_rate"]) == pytest.approx(2e-2)
    u2a, _ = upd(g, s1, params)
    u2b, _ = upd(g, s1b, params)
    np.testing.assert_allclose(np.asarray(u2b["w"]),
                               2.0 * np.asarray(u2a["w"]), rtol=1e-6)
    with pytest.raises(KeyError):
        api.set_hyperparams(s1, nonexistent=1.0)


def test_named_chain_stage_lookup():
    tx = make_optimizer(OptimizerConfig(name="sketchy", rank=8, block_size=32,
                                        update_every=2, weight_decay=1e-4,
                                        schedule="constant"))
    state = tx.init(_params())
    precond = api.get_stage(state, "precond")
    assert isinstance(precond, api.PrecondState)
    assert int(precond.count.value) == 0
    for name in ("clip", "momentum", "weight_decay", "lr"):
        api.get_stage(state, name)  # present, no error
    with pytest.raises(KeyError):
        api.get_stage(state, "nope")


def test_custom_preconditioner_plugs_in():
    """A brand-new optimizer variant = one small Preconditioner; sharding,
    checkpoint manifests, and memory accounting need zero changes."""
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class SignSGD:
        diagonal = True

        def init_block(self, info):
            return {"acc": api.tag(jnp.zeros(info.shape), "second_moment")}

        def update_stats(self, state, G, *, count):
            return {"acc": state["acc"] + jnp.square(G)}

        def refresh(self, state, G, *, count):
            return state

        def precondition(self, state, G, *, count):
            return jnp.sign(G)

    tx = api.scale_by_preconditioner(SignSGD(), api.EngineConfig(graft="none"))
    params = _params()
    state = tx.init(params)
    u, state = tx.update(_grad(0), state, params)
    assert set(np.unique(np.asarray(u["m"]))) <= {-1.0, 0.0, 1.0}
    assert api.second_moment_bytes(state) == sum(
        p.size * 4 for p in jax.tree.leaves(params))
