"""Quantized second-moment pools (core/quantize.py): fp32 bitwise parity
with the unquantized engine, int8 round-trip error bounds (property test),
compressed memory accounting, bf16 convergence tolerance on a
paper_lm_100m-shaped run, cross-dtype checkpoint migration, and scale-array
sharding co-location."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the deterministic sampling shim
    from hypothesis_compat import given, settings, strategies as st

import reference_impls as ref
from repro.core import api, pool, quantize
from repro.core.shampoo import ShampooConfig, ShampooPreconditioner
from repro.core.sketchy import SketchyConfig, SketchyPreconditioner, sketchy


def _params(seed=0):
    """Matrix, vector (diag fallback), and shape-duplicate leaves."""
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    return {"m": mk(48, 20), "v": mk(10), "b": mk(70, 30), "m2": mk(48, 20)}


def _grad(seed):
    return _params(seed + 100)


def _engines(name, qdtype):
    if name == "sketchy":
        precond = SketchyPreconditioner(
            SketchyConfig(rank=8, block_size=32, beta2=0.99, update_every=2))
        ecfg = api.EngineConfig(block_size=32, beta2=0.99, update_every=2,
                                second_moment_dtype=qdtype)
    else:
        precond = ShampooPreconditioner(
            ShampooConfig(block_size=32, beta2=0.99, root_every=2))
        ecfg = api.EngineConfig(block_size=32, beta2=0.99, update_every=2,
                                second_moment_dtype=qdtype)
    return precond, ecfg


# -------------------------------------------------------- fp32 bitwise parity


@pytest.mark.parametrize("name", ["sketchy", "shampoo"])
def test_fp32_storage_bitwise_matches_reference(name):
    """Acceptance criterion: second_moment_dtype="fp32" (the default) stays
    BITWISE identical to the pre-quantization engine, pinned against the
    frozen per-leaf engine in tests/reference_impls.py."""
    precond, ecfg = _engines(name, "fp32")
    params = _params()
    new_tx = api.scale_by_preconditioner(precond, ecfg)
    old_tx = ref.per_leaf_scale_by_preconditioner(precond, ecfg)
    s_new, s_old = new_tx.init(params), old_tx.init(params)
    for t in range(5):
        g = _grad(t)
        u_new, s_new = new_tx.update(g, s_new, params)
        u_old, s_old = old_tx.update(g, s_old, params)
        for a, b in zip(jax.tree.leaves(u_new), jax.tree.leaves(u_old)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fp32_state_structure_unchanged():
    """fp32 storage introduces no QuantizedPool containers — checkpoints and
    shardings of existing runs are untouched."""
    tx = sketchy(SketchyConfig(rank=8, block_size=32))
    state = tx.init(_params())
    for x in jax.tree.leaves(state,
                             is_leaf=lambda v: isinstance(v,
                                                          quantize.QuantizedPool)):
        assert not isinstance(x, quantize.QuantizedPool)


# ----------------------------------------------------- int8 round-trip bound


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 6),
    d=st.integers(1, 24),
    k=st.integers(1, 8),
    log_scale=st.integers(-12, 12),
    stochastic=st.sampled_from([False, True]),
)
def test_int8_roundtrip_error_bound(n, d, k, log_scale, stochastic):
    """Per-element |dequant(quant(x)) - x| <= per-block scale (stochastic
    rounding moves at most one quantization step; deterministic at most
    half), across magnitudes and block shapes.  Zero blocks are exact."""
    rng = np.random.default_rng(n * 1000 + d * 10 + k)
    x = rng.normal(size=(n, d, k)).astype(np.float32) * (2.0 ** log_scale)
    x[0] = 0.0  # always include an all-zero block
    key = jax.random.PRNGKey(7) if stochastic else None
    qp = quantize.quantize_stack(jnp.asarray(x), key=key)
    assert qp.values.dtype == jnp.int8
    assert qp.scale.shape == (n, 1, 1)
    back = np.asarray(quantize.dequantize_stack(qp.values, qp.scale))
    scale = np.asarray(qp.scale)
    bound = scale * (1.0 if stochastic else 0.5) * (1 + 1e-6)
    assert (np.abs(back - x) <= bound).all()
    np.testing.assert_array_equal(back[0], 0.0)


def test_int8_requantize_is_idempotent():
    """Re-quantizing an unchanged dequantized stack is a fixed point (the
    engine re-quantizes every step; off-refresh steps must not random-walk
    the stored sketch)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4, 16, 8)), jnp.float32)
    qp = quantize.quantize_stack(x)
    back = quantize.dequantize_stack(qp.values, qp.scale)
    qp2 = quantize.quantize_stack(back)
    np.testing.assert_array_equal(np.asarray(qp.values), np.asarray(qp2.values))


# --------------------------------------------------------- memory accounting


def test_int8_second_moment_bytes_ratio():
    """Acceptance criterion: int8 pools report <= 0.27x the fp32
    second_moment_bytes (values + scales), via the same metadata traversal
    (works on eval_shape structs — no state materialization)."""
    params = {"w1": jnp.zeros((512, 256), jnp.float32),
              "w2": jnp.zeros((256, 256), jnp.float32)}
    bytes_by = {}
    for dt in ("fp32", "bf16", "int8"):
        tx = sketchy(SketchyConfig(rank=64, block_size=256,
                                   second_moment_dtype=dt))
        bytes_by[dt] = api.second_moment_bytes(jax.eval_shape(tx.init, params))
    assert bytes_by["bf16"] == bytes_by["fp32"] // 2
    ratio = bytes_by["int8"] / bytes_by["fp32"]
    assert ratio <= 0.27, f"int8 ratio {ratio:.3f} > 0.27"


# --------------------------------------------------------- bf16 convergence


def test_bf16_trains_paper_lm_within_tolerance_of_fp32():
    """Acceptance criterion: bf16 second-moment storage reaches a loss
    within tolerance of fp32 on a small synthetic paper_lm_100m-shaped run."""
    from repro.configs.registry import get_reduced
    from repro.core.factory import OptimizerConfig, make_optimizer
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import model as model_lib
    from repro.train.trainer import make_train_step

    cfg = get_reduced("paper_lm_100m")
    steps = 12
    finals = {}
    for dt in ("fp32", "bf16"):
        tx = make_optimizer(OptimizerConfig(
            name="sketchy", learning_rate=5e-3, rank=8, block_size=32,
            update_every=2, total_steps=steps, schedule="constant",
            second_moment_dtype=dt))
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        state = tx.init(params)
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=8))
        step = make_train_step(cfg, tx)  # jitted + donated internally
        losses = []
        for t in range(steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(t).items()}
            params, state, m = step(params, state, batch)
            losses.append(float(m["loss"]))
        finals[dt] = losses
    assert finals["bf16"][-1] < finals["bf16"][0]          # it actually trains
    assert abs(finals["bf16"][-1] - finals["fp32"][-1]) < 0.05 * \
        abs(finals["fp32"][0] - finals["fp32"][-1]) + 0.02


def test_int8_trains_without_nans():
    """int8 storage keeps the full engine (grafting, diag fallback, gating)
    finite over several refresh windows."""
    params = _params()
    tx = sketchy(SketchyConfig(rank=8, block_size=32, update_every=2,
                               second_moment_dtype="int8"))
    state = tx.init(params)
    upd = jax.jit(tx.update)
    for t in range(6):
        u, state = upd(_grad(t), state, params)
    for x in jax.tree.leaves(u):
        assert np.isfinite(np.asarray(x)).all()
    # count the stored int8 leaves: one per pooled matrix-factor stack
    int8_leaves = [x for _, x in api.leaves_with_meta(state)
                   if jnp.asarray(x).dtype == jnp.int8]
    assert int8_leaves, "no int8-stored pool stacks found"


def test_int8_diag_fallback_leaves_quantized():
    """Diag-fallback accumulators (vector/scalar leaves) also store int8
    under second_moment_dtype="int8": whole-leaf (1,)*ndim absmax scale,
    replicated-scale tag, and the dequantized accumulator tracks the fp32
    engine's within the quantization step."""
    params = _params()
    states, taus = {}, {}
    for dt in ("fp32", "int8"):
        tx = sketchy(SketchyConfig(rank=8, block_size=32, beta2=0.99,
                                   update_every=2, second_moment_dtype=dt))
        state = tx.init(params)
        upd = jax.jit(tx.update)
        for t in range(5):
            _, state = upd(_grad(t), state, params)
        states[dt] = state
        # the vector param "v" lands in a diag-fallback leaf
        (leaf,) = [l for l in state.leaves if l.stats is not None]
        taus[dt] = np.asarray(quantize.dequantize_pool(leaf.stats))

    (leaf8,) = [l for l in states["int8"].leaves if l.stats is not None]
    qp = leaf8.stats
    assert isinstance(qp, quantize.QuantizedPool)
    assert api.untag(qp.values).dtype == jnp.int8
    assert api.untag(qp.scale).shape == (1,)          # one whole-leaf scale
    assert qp.scale.meta.shard == "replicate"
    assert qp.values.meta.param_index is not None     # rides the param layout
    # fp32 run keeps plain Tagged stats on the same leaf
    (leaf32,) = [l for l in states["fp32"].leaves if l.stats is not None]
    assert isinstance(leaf32.stats, api.Tagged)
    step = float(api.untag(qp.scale).max())
    assert np.abs(taus["int8"] - taus["fp32"]).max() <= 5 * step + 1e-7


# ------------------------------------------------- cross-dtype checkpointing


@pytest.mark.parametrize("src,dst", [("fp32", "int8"), ("int8", "fp32"),
                                     ("bf16", "fp32"), ("fp32", "bf16"),
                                     ("bf16", "int8"), ("int8", "bf16")])
def test_checkpoint_roundtrip_across_dtype_change(tmp_path, src, dst, ):
    """A checkpoint written under one second_moment_dtype restores into a
    run configured with another: int8 <-> fp32/bf16 re-quantize/dequantize
    on the fly, fp32 <-> bf16 cast in place — and training continues."""
    from repro.train import checkpoint as ckpt

    params = _params()
    mk = lambda dt: sketchy(SketchyConfig(rank=8, block_size=32,
                                          update_every=2, beta2=0.99,
                                          second_moment_dtype=dt))
    tx_src = mk(src)
    state = tx_src.init(params)
    for t in range(3):
        u, state = tx_src.update(_grad(t), state, params)
    d = str(tmp_path)
    ckpt.save(d, 7, {"opt": state})

    tx_dst = mk(dst)
    restored, step, _ = ckpt.restore(d, {"opt": tx_dst.init(params)})
    assert step == 7
    rstate = restored["opt"]

    # the dequantized pools agree up to one quantization step of whichever
    # side is int8 (exact when neither is)
    for key in state.pools:
        a = jax.tree.leaves(quantize.dequantize_pool(state.pools[key]))
        b = jax.tree.leaves(quantize.dequantize_pool(rstate.pools[key]))
        for x, y in zip(a, b):
            x, y = np.asarray(x, np.float32), np.asarray(y, np.float32)
            tol = 0.0
            if "int8" in (src, dst):
                tol += np.abs(x).max() / 127.0
            if "bf16" in (src, dst):
                tol += np.abs(x).max() * 2 ** -7
            np.testing.assert_allclose(x, y, atol=tol + 1e-7)

    # training continues from the restored state in the dst layout
    u, rstate = tx_dst.update(_grad(9), rstate, params)
    for x in jax.tree.leaves(u):
        assert np.isfinite(np.asarray(x)).all()


def test_checkpoint_same_dtype_roundtrip_exact_int8(tmp_path):
    """Same-layout int8 checkpoints restore bit-exactly (no migration)."""
    from repro.train import checkpoint as ckpt

    params = _params()
    tx = sketchy(SketchyConfig(rank=8, block_size=32, update_every=2,
                               second_moment_dtype="int8"))
    state = tx.init(params)
    for t in range(3):
        u, state = tx.update(_grad(t), state, params)
    d = str(tmp_path)
    ckpt.save(d, 1, state)
    restored, _, _ = ckpt.restore(d, tx.init(params))
    got = api.leaves_with_meta(restored)
    want = api.leaves_with_meta(state)
    assert len(got) == len(want)
    for (_, a), (_, b) in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- sharding co-location


def test_scale_arrays_shard_alongside_int8_values():
    """trainer.train_state_shardings gives a QuantizedPool's values and
    scale the SAME leading-dim (opt_blocks) sharding decision — dequantize
    is shard-local."""
    from repro.sharding import rules as rules_lib
    from repro.train.trainer import train_state_shardings

    params = {"w": jnp.zeros((64, 32), jnp.float32),
              "w2": jnp.zeros((64, 32), jnp.float32)}
    tx = sketchy(SketchyConfig(rank=4, block_size=32,
                               second_moment_dtype="int8"))
    state = jax.eval_shape(tx.init, params)
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    rules = rules_lib.MeshRules(mesh=mesh,
                                rules=dict(rules_lib.DEFAULT_LOGICAL_RULES))
    sh = train_state_shardings(state, params, rules)

    # walk the sharded tree for QuantizedPool nodes
    found = []

    def visit(x):
        if isinstance(x, quantize.QuantizedPool):
            found.append(x)
        return x

    jax.tree.map(visit, sh,
                 is_leaf=lambda x: isinstance(x, quantize.QuantizedPool))
    assert found, "no QuantizedPool in sharded state"
    for qp in found:
        v_sh = qp.values.value
        s_sh = qp.scale.value
        assert isinstance(v_sh, NamedSharding)
        assert isinstance(s_sh, NamedSharding)
        assert v_sh.spec[:1] == s_sh.spec[:1]  # same leading-dim decision


# ---------------------------------------------------------------- validation


def test_unknown_second_moment_dtype_rejected():
    with pytest.raises(ValueError, match="second_moment_dtype"):
        api.EngineConfig(second_moment_dtype="fp8")


def test_pool_stats_dequantizes():
    """api.pool_stats returns the f32 compute layout for any storage mode."""
    rng = np.random.default_rng(0)
    params = {f"w{i}": jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
              for i in range(3)}
    tx = sketchy(SketchyConfig(rank=4, block_size=32, update_every=1,
                               second_moment_dtype="int8"))
    state = tx.init(params)
    g = {k: jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
         for k in params}
    u, state = tx.update(g, state, params)
    stats = api.pool_stats(state)
    for x in jax.tree.leaves(stats):
        assert x.dtype == jnp.float32
    index = pool.build_index(((32, 32),) * 3, 32)
    assert jax.tree.leaves(stats)[0].shape[0] == index.total_blocks
