"""Rank-budget allocator (core/sketchy.RankBudget): static-policy parity
with the pre-budget engine, budget conservation, exact Robust-FD mass
folding on shrink, rho-greedy migration, checkpoint migration, and the
deprecated ``rank=`` alias."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the deterministic sampling shim
    from hypothesis_compat import given, settings, strategies as st

from repro.core import api
from repro.core.fd import FDState, fd_resize_batched
from repro.core.pool import allocate_ranks, uniform_ranks
from repro.core.sketchy import (BudgetedSketchStats, RankBudget,
                                SketchyConfig, sketchy)

jax.config.update("jax_enable_x64", False)


def _params():
    return {"w": jnp.zeros((32, 32), jnp.float32),
            "v": jnp.zeros((16, 8), jnp.float32)}


def _grads(i, params):
    key = jax.random.PRNGKey(1000 + i)
    keys = jax.random.split(key, len(params))
    return {name: jax.random.normal(k, p.shape, p.dtype)
            for k, (name, p) in zip(keys, sorted(params.items()))}


def _run(tx, params, steps):
    state = tx.init(params)
    outs = []
    for i in range(steps):
        u, state = tx.update(_grads(i, params), state, params)
        outs.append(u)
    return outs, state


def _assert_trees_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# static policy == pre-budget engine, across the whole engine matrix


@pytest.mark.parametrize("schedule", ["synchronized", "staggered"])
@pytest.mark.parametrize("mode", ["inline", "async"])
@pytest.mark.parametrize("dtype", ["fp32", "bf16", "int8"])
def test_static_policy_bitwise_parity(schedule, mode, dtype):
    """RankBudget(min_k=max_k=r, policy="static") is bitwise-identical to
    the deprecated ``rank=r`` spelling under every refresh_schedule x
    refresh_mode x second_moment_dtype combination."""
    params = _params()
    common = dict(block_size=16, beta2=0.99, update_every=2,
                  refresh_schedule=schedule, refresh_mode=mode,
                  second_moment_dtype=dtype)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        tx_old = sketchy(SketchyConfig(rank=4, **common))
    tx_new = sketchy(SketchyConfig(
        rank_budget=RankBudget(min_k=4, max_k=4, policy="static"), **common))
    outs_old, st_old = _run(tx_old, params, 7)
    outs_new, st_new = _run(tx_new, params, 7)
    _assert_trees_bitwise(outs_old, outs_new)
    _assert_trees_bitwise(st_old, st_new)
    assert api.second_moment_bytes(st_old) == api.second_moment_bytes(st_new)


def test_budgeted_bytes_equal_static_at_same_capacity():
    """rho_greedy at capacity max_k stores byte-identical second-moment
    state to a static run at rank == max_k: k is a role="count" leaf, never
    part of the Fig. 1 budget."""
    params = _params()
    common = dict(block_size=16, update_every=2)
    tx_s = sketchy(SketchyConfig(
        rank_budget=RankBudget(min_k=4, max_k=4), **common))
    tx_b = sketchy(SketchyConfig(
        rank_budget=RankBudget(min_k=2, max_k=4, policy="rho_greedy"),
        **common))
    _, st_s = _run(tx_s, params, 3)
    _, st_b = _run(tx_b, params, 3)
    assert api.second_moment_bytes(st_s) == api.second_moment_bytes(st_b)


# ---------------------------------------------------------------------------
# allocator properties


def _ref_allocate(pressure, total, min_k, max_k):
    """Plain-python greedy waterfill reference."""
    n = len(pressure)
    k = [min_k] * n
    budget = total - n * min_k
    for i in sorted(range(n), key=lambda i: -pressure[i]):
        give = min(budget, max_k - min_k)
        k[i] += give
        budget -= give
    return k


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 12), min_k=st.integers(1, 6), room=st.integers(0, 9),
       slack=st.integers(0, 40), seed=st.integers(0, 10_000))
def test_allocate_ranks_conserves_budget(n, min_k, room, slack, seed):
    """For arbitrary pressure vectors: sum k_b == total exactly and every
    block lands in [min_k, max_k]; matches the plain greedy reference."""
    max_k = min_k + room
    total = min(n * min_k + slack, n * max_k)
    rng = np.random.default_rng(seed)
    pressure = jnp.asarray(rng.random(n), jnp.float32)
    k = np.asarray(allocate_ranks(pressure, total=total, min_k=min_k,
                                  max_k=max_k))
    assert int(k.sum()) == total
    assert (k >= min_k).all() and (k <= max_k).all()
    assert k.tolist() == _ref_allocate(pressure.tolist(), total, min_k, max_k)


def test_uniform_ranks_spreads_remainder():
    k = np.asarray(uniform_ranks(3, 8, 1, 4))
    assert k.tolist() == [3, 3, 2] and k.sum() == 8


def test_resolve_total_validates_feasibility():
    b = RankBudget(total=100, min_k=2, max_k=8)
    with pytest.raises(ValueError, match="infeasible"):
        b.resolve_total(4)          # 100 > 4 * 8
    assert b.resolve_total(20) == 100
    assert RankBudget(min_k=2, max_k=8).resolve_total(5) == 40  # capacity


# ---------------------------------------------------------------------------
# exact Robust-FD mass folding


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 5),
       ell=st.integers(2, 8))
def test_resize_folds_exact_dropped_mass(seed, n, ell):
    """Shrinking block b to k folds exactly sum_{i>=k} s_i into rho and
    zeroes the dropped eigenpairs; growing only unmasks zero columns."""
    rng = np.random.default_rng(seed)
    d = ell + 3
    s = np.sort(rng.random((n, ell)).astype(np.float32), axis=-1)[:, ::-1]
    U = rng.normal(size=(n, d, ell)).astype(np.float32)
    rho = rng.random(n).astype(np.float32)
    state = FDState(eigvecs=jnp.asarray(U), eigvals=jnp.asarray(s.copy()),
                    rho=jnp.asarray(rho))
    new_k = jnp.asarray(rng.integers(1, ell + 1, size=n), jnp.int32)
    out = fd_resize_batched(state, new_k)
    for b in range(n):
        k = int(new_k[b])
        dropped = s[b, k:].sum()
        np.testing.assert_allclose(float(out.rho[b]), rho[b] + dropped,
                                   rtol=1e-6, atol=1e-7)
        assert np.all(np.asarray(out.eigvals)[b, k:] == 0.0)
        assert np.all(np.asarray(out.eigvecs)[b, :, k:] == 0.0)
        np.testing.assert_array_equal(np.asarray(out.eigvals)[b, :k],
                                      s[b, :k])
        np.testing.assert_array_equal(np.asarray(out.eigvecs)[b, :, :k],
                                      U[b, :, :k])
    # growing back to capacity is a no-op on the already-masked state
    regrow = fd_resize_batched(out, jnp.full((n,), ell, jnp.int32))
    _assert_trees_bitwise(out, regrow)


# ---------------------------------------------------------------------------
# rho_greedy migration on a synthetic two-spectrum problem


@pytest.mark.parametrize("dtype,mode", [("fp32", "inline"),
                                        ("int8", "inline"),
                                        ("fp32", "async")])
def test_rho_greedy_shifts_rank_to_high_rho_block(dtype, mode):
    """Two same-shape params, one fed full-spectrum noise (sketch starves,
    high rho) and one rank-1 gradients (no escaped mass): the budget
    migrates toward the noisy block while sum k_b stays at total."""
    params = {"hi": jnp.zeros((32, 32), jnp.float32),
              "lo": jnp.zeros((32, 32), jnp.float32)}
    tx = sketchy(SketchyConfig(
        rank_budget=RankBudget(total=16, min_k=2, max_k=14,
                               policy="rho_greedy", realloc_every=1),
        block_size=32, beta2=0.9, update_every=2,
        second_moment_dtype=dtype, refresh_mode=mode))
    state = tx.init(params)
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(jax.random.PRNGKey(7), (32,))
    v = jax.random.normal(jax.random.PRNGKey(8), (32,))
    for i in range(10):
        key, sub = jax.random.split(key)
        g = {"hi": jax.random.normal(sub, (32, 32)),
             "lo": jnp.outer(u, v)}
        _, state = tx.update(g, state, params)
    alloc = api.rank_allocation(state)
    (k,) = [np.asarray(grp["k"]) for grp in alloc["groups"].values()]
    assert int(k.sum()) == 16 == alloc["total"]
    k_hi, k_lo = int(k[0]), int(k[1])   # pack order: "hi" then "lo"
    assert k_hi > k_lo, (k_hi, k_lo)
    assert k_hi >= 10 and k_lo <= 6


def test_rank_allocation_reports_shares():
    params = _params()
    tx = sketchy(SketchyConfig(
        rank_budget=RankBudget(min_k=2, max_k=6, policy="rho_greedy"),
        block_size=16, update_every=2))
    _, state = _run(tx, params, 3)
    alloc = api.rank_allocation(state)
    shares = np.concatenate([np.asarray(grp["budget_share"]) for grp in
                             alloc["groups"].values()])
    ks = np.concatenate([np.asarray(grp["k"]) for grp in
                         alloc["groups"].values()])
    assert int(ks.sum()) == alloc["total"]
    np.testing.assert_allclose(shares.sum(), 1.0, rtol=1e-6)
    for grp in alloc["groups"].values():
        assert np.asarray(grp["rho"]).shape == np.asarray(grp["k"]).shape


# ---------------------------------------------------------------------------
# checkpoint migration: fixed-rank checkpoints restore into budgeted runs


def test_fixed_rank_checkpoint_restores_into_budgeted(tmp_path):
    from repro.train import checkpoint as ck

    params = _params()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        tx_old = sketchy(SketchyConfig(rank=4, block_size=16, update_every=2))
    _, st_old = _run(tx_old, params, 4)
    ck.save(str(tmp_path), 4, st_old)

    tx_new = sketchy(SketchyConfig(
        rank_budget=RankBudget(min_k=2, max_k=6, policy="rho_greedy",
                               realloc_every=1),
        block_size=16, update_every=2))
    template = tx_new.init(params)
    restored, step, _ = ck.restore(str(tmp_path), template)
    assert step == 4
    # k leaves fell back to the template's init-time uniform allocation
    alloc = api.rank_allocation(restored)
    ks = np.concatenate([np.asarray(g["k"]) for g in
                         alloc["groups"].values()])
    assert int(ks.sum()) == alloc["total"]
    # and the run continues (realloc re-fits the budget to restored spectra)
    state = restored
    for i in range(4, 8):
        _, state = tx_new.update(_grads(i, params), state, params)
    alloc2 = api.rank_allocation(state)
    ks2 = np.concatenate([np.asarray(g["k"]) for g in
                          alloc2["groups"].values()])
    assert int(ks2.sum()) == alloc["total"]

    # same-structure restore stays exact
    r2, _, _ = ck.restore(str(tmp_path), tx_old.init(params))
    _assert_trees_bitwise(st_old, r2)


# ---------------------------------------------------------------------------
# API surface: deprecation alias, validation, hyperparam rejection


def test_rank_alias_deprecated_but_equivalent():
    with pytest.warns(DeprecationWarning, match="rank_budget"):
        cfg = SketchyConfig(rank=8)
    assert cfg.rank == 8
    assert cfg.rank_budget == RankBudget(min_k=8, max_k=8, policy="static")
    # no warning when rank_budget is passed explicitly
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg2 = SketchyConfig(rank_budget=RankBudget(min_k=8, max_k=8))
        assert cfg2.rank == 8           # normalized legacy read
        # dataclasses.replace round-trips the normalized pair
        cfg3 = dataclasses.replace(cfg2, update_every=5)
        assert cfg3.rank_budget == cfg2.rank_budget
        SketchyConfig()                  # default: paper rank 256, static
    with pytest.raises(ValueError, match="not both"):
        SketchyConfig(rank=8, rank_budget=RankBudget(min_k=4, max_k=4))


def test_rank_budget_validation():
    with pytest.raises(ValueError, match="policy"):
        RankBudget(policy="bogus")
    with pytest.raises(ValueError, match="min_k"):
        RankBudget(min_k=8, max_k=4)
    with pytest.raises(ValueError, match="realloc_every"):
        RankBudget(realloc_every=0)


def test_set_hyperparams_rejects_unknown_key():
    from repro.core.factory import OptimizerConfig, make_optimizer
    tx = make_optimizer(OptimizerConfig(rank=4, block_size=16,
                                        update_every=2, total_steps=10))
    state = tx.init(_params())
    with pytest.raises(KeyError, match="unknown hyperparameter 'bogus'"):
        api.set_hyperparams(state, bogus=1.0)
    # known keys still go through
    state2 = api.set_hyperparams(state, beta2=0.95)
    assert float(api.get_hyperparams(state2)["beta2"]) == pytest.approx(0.95)
