"""Serving stack tests: continuous batching vs the old static-batch path,
slot reuse, per-request decode knobs, FD gradient monitor policy, runtime
hyperparameter mutation (no retrace), and the end-to-end serve scenario
(load generator -> traffic shift -> monitor trip -> S-AdaGrad adaptation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.serve import (ADAPT, PAUSE, STEADY, AdaptConfig, Engine,
                         GradientMonitor, LoadGenerator, MonitorConfig,
                         OnlineAdapter, Request, ServeConfig, TrafficConfig)

KEY = jax.random.PRNGKey(0)
MAX_SEQ = 24


def _params(arch):
    cfg = get_reduced(arch)
    return cfg, model_lib.init_params(cfg, KEY)


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=(n,), dtype=np.int32)
            for n in lens]


def _static_generate(cfg, params, requests, max_seq):
    """The pre-redesign static-batch loop (greedy): pads every request to a
    common grid, runs ``max(max_new_tokens)`` steps for the whole batch,
    truncates outputs per request.  Kept in-test as the parity reference."""
    B = len(requests)
    cache = cache_lib.init_cache(cfg, B, max_seq)
    step = jax.jit(lambda p, c, b, pos: cache_lib.decode_step(cfg, p, c,
                                                              b, pos))
    prompts = [r.prompt for r in requests]
    max_p = max(len(p) for p in prompts)
    max_new = max(r.max_new_tokens for r in requests)
    toks = np.zeros((B, max_p), np.int32)
    plens = np.array([len(p) for p in prompts], np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    outs = [[] for _ in range(B)]
    last = jnp.asarray(toks[:, :1])
    for pos in range(max_p + max_new - 1):
        logits, cache = step(params, cache, {"token": last},
                             jnp.asarray(pos, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        cur = np.zeros((B,), np.int32)
        for i in range(B):
            if pos + 1 < plens[i]:
                cur[i] = toks[i, pos + 1]
            else:
                cur[i] = nxt[i]
                if len(outs[i]) < requests[i].max_new_tokens:
                    outs[i].append(int(nxt[i]))
        last = jnp.asarray(cur)[:, None]
    return outs


@pytest.mark.parametrize("arch", ["paper_lm_100m", "mamba2_370m",
                                  "zamba2_7b"])
def test_continuous_batching_matches_static_batch(arch):
    """Greedy tokens from the session API == the old static-batch path, for
    ragged prompt lengths and ragged max_new_tokens, across cache
    families (attention / ssm / hybrid)."""
    cfg, params = _params(arch)
    reqs = [Request(p, max_new_tokens=n) for p, n in
            zip(_prompts(cfg, [5, 3, 6]), [4, 6, 3])]
    want = _static_generate(cfg, params, reqs, MAX_SEQ)

    eng = Engine(cfg, params, ServeConfig(batch=3, max_seq=MAX_SEQ))
    handles = [eng.submit(r) for r in reqs]
    eng.drain()
    for h, w in zip(handles, want):
        assert h.tokens == w
        assert h.done and len(h.tokens) == h.request.max_new_tokens


def test_slot_reuse_parity():
    """More requests than lanes: finished lanes are wiped and reused, and
    every request still decodes exactly its solo-run tokens even though
    its co-tenants (and the lane's previous occupant) differ."""
    cfg, params = _params("paper_lm_100m")
    reqs = [Request(p, max_new_tokens=n) for p, n in
            zip(_prompts(cfg, [4, 6, 3, 5, 4]), [3, 6, 4, 2, 5])]

    eng = Engine(cfg, params, ServeConfig(batch=2, max_seq=MAX_SEQ))
    handles = [eng.submit(r) for r in reqs]
    assert eng.active == 2 and eng.pending == 3
    eng.drain()

    # solo reference: ONE single-lane engine serving sequentially — which
    # itself exercises the lane wipe between occupants
    solo = Engine(cfg, params, ServeConfig(batch=1, max_seq=MAX_SEQ))
    for h in handles:
        ref = solo.submit(Request(h.request.prompt,
                                  h.request.max_new_tokens))
        solo.drain()
        assert h.tokens == ref.tokens, f"request {h.id}"


def test_slot_reuse_wipes_ssm_state():
    """Cumulative-state family: a reused lane must not leak the previous
    occupant's SSM/conv state."""
    cfg, params = _params("mamba2_370m")
    (p0, p1) = _prompts(cfg, [6, 4], seed=3)

    eng = Engine(cfg, params, ServeConfig(batch=1, max_seq=MAX_SEQ))
    eng.submit(Request(p0, max_new_tokens=4))
    eng.drain()
    h1 = eng.submit(Request(p1, max_new_tokens=5))
    eng.drain()

    fresh = Engine(cfg, params, ServeConfig(batch=1, max_seq=MAX_SEQ))
    ref = fresh.submit(Request(p1, max_new_tokens=5))
    fresh.drain()
    assert h1.tokens == ref.tokens


def test_per_request_max_new_and_temperature():
    """The old path generated max(...) tokens for everyone and sampled at a
    batch-wide temperature; now both are per-lane: a greedy request is
    bitwise-unaffected by a hot co-tenant and each stops at its own
    budget."""
    cfg, params = _params("paper_lm_100m")
    (pg, ph) = _prompts(cfg, [5, 5], seed=1)

    eng = Engine(cfg, params, ServeConfig(batch=2, max_seq=MAX_SEQ, seed=7))
    h_greedy = eng.submit(Request(pg, max_new_tokens=3, temperature=0.0))
    h_hot = eng.submit(Request(ph, max_new_tokens=8, temperature=1.5))
    eng.drain()
    assert len(h_greedy.tokens) == 3
    assert len(h_hot.tokens) == 8

    solo = Engine(cfg, params, ServeConfig(batch=1, max_seq=MAX_SEQ))
    ref = solo.submit(Request(pg, max_new_tokens=3, temperature=0.0))
    solo.drain()
    assert h_greedy.tokens == ref.tokens


def test_engine_and_request_validation():
    cfg, params = _params("paper_lm_100m")
    bad = get_reduced("musicgen_large")
    with pytest.raises(ValueError, match="token-input"):
        Engine(bad, {}, ServeConfig())

    eng = Engine(cfg, params, ServeConfig(batch=2, max_seq=16))
    (p,) = _prompts(cfg, [10])
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(Request(p, max_new_tokens=12))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(p, max_new_tokens=0))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(np.zeros((0,), np.int32)))
    with pytest.raises(ValueError, match="lanes"):
        eng.generate([Request(p, max_new_tokens=2)] * 3)


def test_generate_compat_wrapper_no_overgeneration():
    """Deprecated Engine.generate keeps the old signature but honors each
    request's own max_new_tokens."""
    cfg, params = _params("paper_lm_100m")
    reqs = [Request(p, max_new_tokens=n) for p, n in
            zip(_prompts(cfg, [4, 4]), [2, 6])]
    eng = Engine(cfg, params, max_seq=MAX_SEQ, batch=2)   # legacy kwargs
    results = eng.generate(reqs)
    assert [len(r.tokens) for r in results] == [2, 6]
    want = _static_generate(cfg, params, reqs, MAX_SEQ)
    assert [r.tokens for r in results] == want


# ---------------------------------------------------------------------------
# monitor


def _lowrank_grads(rng, basis, n, scale=1.0, noise=0.0):
    d = basis.shape[0]
    out = []
    for _ in range(n):
        g = basis @ rng.standard_normal(basis.shape[1])
        if noise:
            g = g + noise * rng.standard_normal(d)
        out.append(scale * g.astype(np.float32))
    return out


def test_monitor_detects_distribution_shift():
    """Steady low-rank traffic reads steady; an injected shift (subspace
    rotation + rank blow-up) pushes BOTH the drift angle and the escaped-
    mass pressure over threshold and triggers adaptation."""
    d, ell, window = 64, 8, 16
    rng = np.random.default_rng(0)
    basis = np.linalg.qr(rng.standard_normal((d, 3)))[0]
    mon = GradientMonitor(d, MonitorConfig(
        ell=ell, window=window, top_k=3, drift_threshold=0.8,
        pressure_threshold=0.2, warmup_windows=1))

    readings = []
    for g in _lowrank_grads(rng, basis, 3 * window):      # steady phase
        r = mon.observe(g)
        if r:
            readings.append(r)
    assert all(r.decision == STEADY for r in readings)
    assert all(r.pressure < 0.05 for r in readings)

    rot = np.linalg.qr(rng.standard_normal((d, d)))[0]    # full-rank shift
    shifted = []
    for g in _lowrank_grads(rng, rot, 2 * window):
        r = mon.observe(g)
        if r:
            shifted.append(r)
    assert any(r.decision == ADAPT for r in shifted)
    trip = next(r for r in shifted if r.decision == ADAPT)
    assert trip.drift_angle > 0.8          # subspace rotated
    assert trip.pressure > 0.2             # rank-ell sketch overflows


def test_monitor_pauses_on_magnitude_spike_then_recovers():
    """A 100x gradient-energy burst reads as suspected bad traffic (pause,
    not adapt), and is kept out of the EMA so the next honest window is
    judged against pre-spike energy."""
    d, window = 32, 8
    rng = np.random.default_rng(1)
    basis = np.linalg.qr(rng.standard_normal((d, 3)))[0]
    mon = GradientMonitor(d, MonitorConfig(
        ell=8, window=window, top_k=3, spike_factor=25.0,
        drift_threshold=np.pi, pressure_threshold=1.1))   # isolate spike

    for g in _lowrank_grads(rng, basis, 3 * window):
        mon.observe(g)
    ema_before = mon._eig_ema
    for g in _lowrank_grads(rng, basis, window, scale=100.0):
        r = mon.observe(g)
    assert r.decision == PAUSE
    assert mon._eig_ema == ema_before      # spike excluded from the EMA
    for g in _lowrank_grads(rng, basis, window):
        r = mon.observe(g)
    assert r.decision != PAUSE             # honest traffic resumes


def test_monitor_validation():
    with pytest.raises(ValueError, match="top_k"):
        MonitorConfig(ell=4, top_k=8)
    mon = GradientMonitor(8)
    with pytest.raises(ValueError, match="dim"):
        mon.observe(np.zeros(9, np.float32))


# ---------------------------------------------------------------------------
# online adaptation


def _feedback(cfg, seed=1, seq=16, batch=4):
    return SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=seed))


def test_adapter_reduces_feedback_loss():
    cfg, params = _params("paper_lm_100m")
    batch = _feedback(cfg).batch(0)
    ad = OnlineAdapter(cfg, params, AdaptConfig(lr=0.1, beta2=0.95, ell=8))
    loss0, g = ad.grad(params, batch)
    assert g.shape == (ad.d,) and np.isfinite(float(loss0))
    p = params
    for _ in range(5):
        p, loss = ad.step(p, batch)
    assert float(loss) < float(loss0)
    # only the head leaf moved
    assert not np.array_equal(np.asarray(p["lm_head"]),
                              np.asarray(params["lm_head"]))
    np.testing.assert_array_equal(np.asarray(p["embed"]),
                                  np.asarray(params["embed"]))


def test_set_hyperparams_mid_serve_no_retrace():
    """api.set_hyperparams mutates lr/beta2 in optimizer state: takes
    effect on the next step with no retrace of the jitted update."""
    cfg, params = _params("paper_lm_100m")
    batch = _feedback(cfg).batch(0)
    ad = OnlineAdapter(cfg, params, AdaptConfig(lr=0.1, beta2=0.95))
    p, _ = ad.step(params, batch)
    assert ad.trace_count == 1

    ad.set_hyperparams(learning_rate=0.0)
    p2, _ = ad.step(p, batch)
    assert ad.trace_count == 1             # no retrace
    np.testing.assert_array_equal(np.asarray(p2["lm_head"]),
                                  np.asarray(p["lm_head"]))   # lr=0 freezes

    ad.set_hyperparams(learning_rate=0.2, beta2=0.5)
    p3, _ = ad.step(p2, batch)
    assert ad.trace_count == 1
    assert not np.array_equal(np.asarray(p3["lm_head"]),
                              np.asarray(p2["lm_head"]))
    assert ad.hyperparams["learning_rate"] == pytest.approx(0.2)
    with pytest.raises(KeyError, match="unknown"):
        ad.set_hyperparams(nope=1.0)


# ---------------------------------------------------------------------------
# end to end


def test_loadgen_deterministic_shapes():
    cfg = get_reduced("paper_lm_100m")
    gen = LoadGenerator(TrafficConfig(shape="step", rate=1.0, ticks=12,
                                      step_at=6, step_mult=3.0,
                                      prompt_len=4, new_tokens=3),
                        cfg.vocab_size)
    counts = [len(gen.arrivals(t)) for t in range(12)]
    assert counts == [len(gen.arrivals(t)) for t in range(12)]   # replayable
    assert gen.rate_at(0) == 1.0 and gen.rate_at(6) == 3.0
    assert sum(counts[6:]) > sum(counts[:6])
    req = gen.arrivals(1)[0] if counts[1] else gen.arrivals(4)[0]
    assert req.prompt.shape == (4,) and req.max_new_tokens == 3
    with pytest.raises(ValueError, match="shape"):
        TrafficConfig(shape="sawtooth")


def test_e2e_shift_trips_monitor_and_adaptation_recovers():
    """The acceptance scenario: a load generator drives the engine while
    feedback batches stream through the monitor.  Steady traffic (a fixed
    query mix) keeps the monitor quiet; an injected label shift rotates
    the feedback-gradient subspace, the drift signal trips, and the
    S-AdaGrad adaptation steps measurably reduce loss on the shifted
    distribution."""
    cfg, params = _params("paper_lm_100m")
    gen = LoadGenerator(TrafficConfig(rate=1.0, ticks=12, prompt_len=4,
                                      new_tokens=3, seed=2), cfg.vocab_size)
    eng = Engine(cfg, params, ServeConfig(batch=2, max_seq=MAX_SEQ))
    ad = OnlineAdapter(cfg, params, AdaptConfig(lr=0.3, beta2=0.9, ell=8))
    mon = GradientMonitor(ad.d, MonitorConfig(
        ell=8, window=3, top_k=3, drift_threshold=0.9,
        pressure_threshold=1.1, spike_factor=1e9, warmup_windows=1))

    # steady phase: recurring query mix — a small pool of feedback batches
    pool = [_feedback(cfg, seed=5).batch(i) for i in range(3)]

    def shifted(batch, shift=17):
        out = dict(batch)
        out["labels"] = (batch["labels"] + shift) % cfg.vocab_size
        return out

    served = []
    for tick in range(6):                          # steady traffic
        for r in gen.arrivals(tick):
            served.append(eng.submit(r))
        eng.step()
        _, g = ad.grad(params, pool[tick % 3])
        mon.observe(g)
    steady = list(mon.readings)
    assert steady and all(r.decision == STEADY for r in steady)

    shifted_batches = [shifted(b) for b in pool]
    loss_before = float(ad.grad(params, shifted_batches[0])[0])
    adapted = params
    tripped = False
    for tick in range(6, 12):                      # shifted traffic
        for r in gen.arrivals(tick):
            served.append(eng.submit(r))
        eng.step()
        batch = shifted_batches[tick % 3]
        _, g = ad.grad(adapted, batch)
        reading = mon.observe(g)
        if reading is not None and reading.decision == ADAPT:
            tripped = True
        if tripped:
            adapted, _ = ad.step(adapted, batch)
            eng.params = adapted               # serve the adapted weights
    eng.drain()

    assert tripped, [str(r) for r in mon.readings]
    trip = next(r for r in mon.readings if r.decision == ADAPT)
    assert trip.window >= len(steady)          # tripped only after shift
    loss_after = float(ad.grad(adapted, shifted_batches[0])[0])
    assert loss_after < loss_before - 0.05, (loss_before, loss_after)

    assert all(h.done for h in served)         # traffic fully served
    assert all(len(h.tokens) == h.request.max_new_tokens for h in served)
