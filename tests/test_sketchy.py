"""S-Shampoo behaviour: full-rank equivalence with dense Shampoo, kernels
path, step-skipping, memory accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, blocking
from repro.core.adam import AdamConfig, adam, second_moment_bytes as adam_b
from repro.core.shampoo import (ShampooConfig, shampoo,
                                second_moment_bytes as shampoo_b)
from repro.core.sketchy import (SketchyConfig, sketchy,
                                second_moment_bytes as sketchy_b)
from repro.core.transform import apply_updates


def _quadratic_problem(seed=0, m=24, n=16, batch=64):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(batch, m)), jnp.float32)
    W = jnp.asarray(rng.normal(size=(m, n)) * 0.3, jnp.float32)
    Y = X @ W

    def loss(p):
        return jnp.mean((X @ p["w"] - Y) ** 2)

    return loss, {"w": jnp.zeros((m, n), jnp.float32)}


def test_full_rank_matches_dense_shampoo():
    """rank >= dim & update_every=1 => S-Shampoo == Shampoo (up to fp error).

    This is the reproduction anchor: the sketch with no escaped mass must
    recover the exact Kronecker preconditioner."""
    loss, params = _quadratic_problem()
    m, n = params["w"].shape
    skt = sketchy(SketchyConfig(rank=max(m, n), block_size=64, beta2=0.99,
                                update_every=1, graft="rmsprop_normalized",
                                matrix_eps=1e-6))
    shp = shampoo(ShampooConfig(block_size=64, beta2=0.99, root_every=1,
                                graft="rmsprop_normalized", matrix_eps=1e-6))
    s_state, h_state = skt.init(params), shp.init(params)
    p_s, p_h = params, params
    for t in range(25):
        g_s = jax.grad(loss)(p_s)
        g_h = jax.grad(loss)(p_h)
        u_s, s_state = skt.update(g_s, s_state, p_s)
        u_h, h_state = shp.update(g_h, h_state, p_h)
        a = np.asarray(u_s["w"], np.float64).ravel()
        b = np.asarray(u_h["w"], np.float64).ravel()
        cos = a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-30)
        assert cos > 0.995, cos   # same direction up to fp/eigh noise
        assert abs(np.linalg.norm(a) / np.linalg.norm(b) - 1) < 0.02
        p_s = apply_updates(p_s, jax.tree.map(lambda u: -0.05 * u, u_s))
        p_h = apply_updates(p_h, jax.tree.map(lambda u: -0.05 * u, u_h))


def test_sketchy_converges_on_quadratic():
    loss, params = _quadratic_problem(seed=1)
    tx = sketchy(SketchyConfig(rank=8, block_size=64, beta2=0.99,
                               update_every=2))
    state = tx.init(params)
    p = params
    l0 = float(loss(p))
    for _ in range(60):
        u, state = tx.update(jax.grad(loss)(p), state, p)
        p = apply_updates(p, jax.tree.map(lambda x: -0.05 * x, u))
    assert float(loss(p)) < 0.05 * l0


def test_kernel_path_matches_jnp_path():
    """kernel_backend="pallas" (interpret-mode batched Pallas gram + lowrank)
    == the pure-jnp "xla" backend."""
    loss, params = _quadratic_problem(seed=2)
    cfg = dict(rank=8, block_size=64, beta2=0.99, update_every=1)
    tx_a = sketchy(SketchyConfig(**cfg, kernel_backend="xla"))
    tx_b = sketchy(SketchyConfig(**cfg, kernel_backend="pallas"))
    sa, sb = tx_a.init(params), tx_b.init(params)
    p = params
    for _ in range(4):
        g = jax.grad(loss)(p)
        ua, sa = tx_a.update(g, sa, p)
        ub, sb = tx_b.update(g, sb, p)
        np.testing.assert_allclose(np.asarray(ua["w"]), np.asarray(ub["w"]),
                                   rtol=1e-3, atol=1e-5)
        p = apply_updates(p, jax.tree.map(lambda x: -0.05 * x, ua))


def test_step_skipping_updates_every_k():
    """FD state changes only on update_every boundaries (paper §6)."""
    loss, params = _quadratic_problem(seed=3)
    tx = sketchy(SketchyConfig(rank=8, block_size=64, update_every=3))
    state = tx.init(params)
    p = params
    prev = None
    changed = []
    for t in range(7):
        u, state = tx.update(jax.grad(loss)(p), state, p)
        cur = np.asarray(api.pool_stats(state).left.eigvals)
        if prev is not None:
            changed.append(not np.allclose(cur, prev))
        prev = cur.copy()
        p = apply_updates(p, jax.tree.map(lambda x: -0.01 * x, u))
    # stats fire at counts 0, 3, 6 -> eigvals change between t=2->3 and
    # t=5->6 (0-based t; first refresh is the baseline `prev`)
    assert changed == [False, False, True, False, False, True]


def test_memory_sublinear_vs_shampoo_and_adam():
    """Paper Fig. 1: second-moment bytes sketchy O((m+n)l) < adam O(mn) <
    shampoo O(m^2+n^2) for rectangular blocks with l << min(m, n)."""
    params = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    skt = sketchy(SketchyConfig(rank=64, block_size=1024))
    shp = shampoo(ShampooConfig(block_size=1024))
    adm = adam(AdamConfig())
    b_skt = sketchy_b(skt.init(params))
    b_shp = shampoo_b(shp.init(params))
    b_adm = adam_b(adm.init(params))
    assert b_skt < b_adm < b_shp
    # exact: sketchy 2*(d*l + l + 1)*4, shampoo 2*d^2*4, adam d^2*4
    assert b_shp == 2 * 1024 * 1024 * 4
    assert b_adm == 1024 * 1024 * 4
    assert b_skt == 2 * (1024 * 64 + 64 + 1) * 4


@pytest.mark.parametrize("shape", [(10,), (48, 20), (3, 40, 24), (130, 70)])
def test_sketchy_handles_all_shapes(shape):
    rng = np.random.default_rng(0)
    params = {"p": jnp.asarray(rng.normal(size=shape), jnp.float32)}
    tx = sketchy(SketchyConfig(rank=8, block_size=32, update_every=1))
    state = tx.init(params)
    g = {"p": jnp.asarray(rng.normal(size=shape), jnp.float32)}
    u, state = tx.update(g, state, params)
    assert u["p"].shape == shape
    assert not bool(jnp.isnan(u["p"]).any())
