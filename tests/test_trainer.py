"""Trainer-level behaviour: microbatching, optimizer integration, loss
improvement on structured data."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_reduced
from repro.core.factory import OptimizerConfig, make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as model_lib
from repro.train.trainer import make_train_step


def test_microbatching_matches_full_batch():
    cfg = get_reduced("paper_lm_100m")
    tx = make_optimizer(OptimizerConfig(name="adam", learning_rate=1e-2,
                                        schedule="constant", grad_clip=None))
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    state = tx.init(params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

    # donate=False: params/state feed both step functions
    full = jax.jit(make_train_step(cfg, tx, donate=False))
    micro = jax.jit(make_train_step(cfg, tx, microbatches=4, donate=False))
    p1, _, m1 = full(params, state, batch)
    p2, _, m2 = micro(params, state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-2, atol=1e-4)


def test_sketchy_trains_lm_loss_down():
    cfg = get_reduced("paper_lm_100m")
    tx = make_optimizer(OptimizerConfig(
        name="sketchy", learning_rate=5e-3, rank=8, block_size=32,
        update_every=2, total_steps=40, schedule="constant",
        weight_decay=0.0))
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    state = tx.init(params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8))
    step = make_train_step(cfg, tx)  # jitted + donated internally
    losses = []
    for t in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch(t).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[:3] + losses[-3:]


def test_train_step_donates_buffers():
    """make_train_step donates params + opt_state: the inputs are deleted
    after the call (XLA reused their buffers for the outputs) and the live
    array population stays flat across steps — no extra steady-state copy
    of the model or optimizer state, in either refresh mode."""
    cfg = get_reduced("paper_lm_100m")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=4))
    for mode in ("inline", "async"):
        tx = make_optimizer(OptimizerConfig(
            name="sketchy", learning_rate=1e-3, rank=8, block_size=32,
            update_every=2, total_steps=12, schedule="constant",
            refresh_mode=mode))
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        state = tx.init(params)
        step = make_train_step(cfg, tx)

        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        old_p, old_s = params, state
        params, state, _ = step(params, state, batch)
        jax.block_until_ready(params)
        # the donated inputs are gone — no second copy survives the step
        assert all(x.is_deleted() for x in jax.tree.leaves(old_p)), mode
        assert all(x.is_deleted() for x in jax.tree.leaves(old_s)), mode

        counts = []
        for t in range(1, 7):
            batch = {k: jnp.asarray(v) for k, v in data.batch(t).items()}
            params, state, m = step(params, state, batch)
            jax.block_until_ready(m["loss"])
            counts.append(sum(not a.is_deleted() for a in jax.live_arrays()))
        # steady state: the live-array population does not grow step over
        # step (donation means outputs alias inputs, nothing accumulates)
        assert max(counts) - min(counts) <= 2, (mode, counts)
        del params, state, old_p, old_s, tx, step
