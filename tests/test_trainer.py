"""Trainer-level behaviour: microbatching, optimizer integration, loss
improvement on structured data."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_reduced
from repro.core.factory import OptimizerConfig, make_optimizer
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import model as model_lib
from repro.train.trainer import make_train_step


def test_microbatching_matches_full_batch():
    cfg = get_reduced("paper_lm_100m")
    tx = make_optimizer(OptimizerConfig(name="adam", learning_rate=1e-2,
                                        schedule="constant", grad_clip=None))
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    state = tx.init(params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

    full = jax.jit(make_train_step(cfg, tx))
    micro = jax.jit(make_train_step(cfg, tx, microbatches=4))
    p1, _, m1 = full(params, state, batch)
    p2, _, m2 = micro(params, state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-2, atol=1e-4)


def test_sketchy_trains_lm_loss_down():
    cfg = get_reduced("paper_lm_100m")
    tx = make_optimizer(OptimizerConfig(
        name="sketchy", learning_rate=5e-3, rank=8, block_size=32,
        update_every=2, total_steps=40, schedule="constant",
        weight_decay=0.0))
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    state = tx.init(params)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8))
    step = jax.jit(make_train_step(cfg, tx))
    losses = []
    for t in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch(t).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[:3] + losses[-3:]
